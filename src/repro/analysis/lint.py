"""AST-based NUMA-contract linter.

Each contract the repo's correctness/perf story depends on gets exactly one
implementation: a named :class:`Rule` in the registry below. The tier-1
tests invoke the same registry (``tests/test_analysis_lint.py``), so a
contract cannot drift between "what CI greps for" and "what the tests
assert" — the grep scans this package replaced used to live copy-pasted in
three different test files.

Run over the tree::

    PYTHONPATH=src python -m repro.analysis            # advisory rules warn
    PYTHONPATH=src python -m repro.analysis --strict   # advisory rules fail

Adding a rule: write a function taking the list of parsed
:class:`Module` objects and returning :class:`Violation` s, then decorate
it with :func:`rule`. Rules must be pure AST/source checks — no imports of
the scanned code, so the linter runs even when the tree is broken enough
that importing it would crash.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import pathlib
import sys
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Module",
    "Rule",
    "RULES",
    "Violation",
    "collect_modules",
    "lint_source",
    "main",
    "repo_root",
    "rule",
    "run_rules",
]

#: Directories (relative to the repo root) the linter scans.
SCAN_DIRS: Tuple[str, ...] = ("src", "benchmarks", "examples", "tests")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One contract breach at a specific source location."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Module:
    """A parsed source file handed to every rule."""

    path: str  # repo-relative posix path
    source: str
    tree: ast.AST


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    check: Callable[[Sequence[Module]], List[Violation]]
    #: Advisory rules report but only fail the run under ``--strict``.
    advisory: bool = False


RULES: Dict[str, Rule] = {}


def rule(name: str, description: str, advisory: bool = False):
    """Register ``fn`` as the single implementation of a contract."""

    def deco(fn: Callable[[Sequence[Module]], List[Violation]]):
        if name in RULES:  # pragma: no cover - registry misuse
            raise ValueError(f"duplicate rule {name!r}")
        RULES[name] = Rule(name, description, fn, advisory)
        return fn

    return deco


# --- shared AST helpers -------------------------------------------------------


def _identifiers(node: ast.AST) -> Iterable[Tuple[str, int]]:
    """Yield every (identifier, lineno) referenced in ``node``.

    Covers bare names, attribute accesses, keyword-argument names, and
    function parameters — but *not* string literals or comments, which is
    the point of moving off the text scans: a docstring that mentions a
    forbidden symbol is fine; code that names it is not.
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id, sub.lineno
        elif isinstance(sub, ast.Attribute):
            yield sub.attr, sub.lineno
        elif isinstance(sub, ast.keyword) and sub.arg is not None:
            yield sub.arg, sub.value.lineno
        elif isinstance(sub, ast.arg):
            yield sub.arg, sub.lineno
        elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield sub.name, sub.lineno
        elif isinstance(sub, ast.ImportFrom):
            for alias in sub.names:
                yield alias.name, sub.lineno
        elif isinstance(sub, ast.Import):
            for alias in sub.names:
                yield alias.name.split(".")[0], sub.lineno


def _call_name(call: ast.Call) -> Optional[str]:
    """The trailing identifier of a call target (``a.b.f(...)`` -> ``f``)."""
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _in_dir(mod: Module, rel_dir: str) -> bool:
    return mod.path.startswith(rel_dir.rstrip("/") + "/")


# --- rules --------------------------------------------------------------------


_VERSIONED_JAX = ("CompilerParams", "TPUCompilerParams", "AxisType")


@rule(
    "compat-only-versioned-jax",
    "version-dependent JAX symbols (CompilerParams / TPUCompilerParams / "
    "AxisType) may only be named by src/repro/compat.py, so the next JAX "
    "bump stays a one-file change",
)
def check_versioned_jax(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if mod.path == "src/repro/compat.py":
            continue
        for ident, line in _identifiers(mod.tree):
            if ident in _VERSIONED_JAX:
                out.append(Violation(
                    "compat-only-versioned-jax", mod.path, line,
                    f"{ident} referenced outside compat.py — route through "
                    "repro.compat (tpu_compiler_params / make_mesh)",
                ))
    return out


#: Per-file identifier bans at the former dispatch sites. These files
#: consume AttentionPlans; none of them may thread ``q_offset`` /
#: ``mapping_name`` by hand, look up ``PAPER_MAPPINGS``, or hand-roll a
#: ``MappingConfig`` past the plan layer. kernels/ops.py dispatches plans
#: but the scoring bodies must live in plan.py.
_PLAN_SITE_BANS: Dict[str, Tuple[str, ...]] = {
    "src/repro/models/attention.py": (
        "q_offset", "mapping_name", "PAPER_MAPPINGS", "resolve_mapping",
        "MappingConfig",
    ),
    "src/repro/models/transformer.py": (
        "q_offset", "mapping_name", "PAPER_MAPPINGS", "resolve_mapping",
        "MappingConfig",
    ),
    "src/repro/serving/engine.py": (
        "q_offset", "mapping_name", "PAPER_MAPPINGS", "resolve_mapping",
        "MappingConfig",
    ),
    "src/repro/serving/backends.py": (
        "q_offset", "mapping_name", "PAPER_MAPPINGS", "resolve_mapping",
        "MappingConfig",
    ),
    "src/repro/serving/scheduler.py": (
        "q_offset", "mapping_name", "PAPER_MAPPINGS", "resolve_mapping",
        "MappingConfig",
    ),
    "src/repro/kernels/ops.py": (
        "_resolve_mapping_cached", "_resolve_kv_layout_cached",
        "PAPER_MAPPINGS", "use_interpret",
    ),
}


@rule(
    "plan-dispatch-only",
    "dispatch sites consume AttentionPlans only: no out-of-band "
    "mapping_name/q_offset threading or PAPER_MAPPINGS lookups past the "
    "plan layer",
)
def check_plan_dispatch(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        banned = _PLAN_SITE_BANS.get(mod.path)
        if not banned:
            continue
        for ident, line in _identifiers(mod.tree):
            if ident in banned:
                out.append(Violation(
                    "plan-dispatch-only", mod.path, line,
                    f"{ident} at a plan-dispatch site — schedule policy "
                    "belongs in kernels/plan.py; thread an AttentionPlan "
                    "instead",
                ))
    return out


_LEGACY_ENGINES = ("ServingEngine", "PagedServingEngine")
_LEGACY_ALLOWED = ("src/repro/serving/", "tests/test_serving.py")


@rule(
    "no-legacy-engine-construction",
    "the deprecated ServingEngine/PagedServingEngine shims may only be "
    "constructed inside src/repro/serving/ (and the shim tests); everything "
    "else goes through LLMEngine",
)
def check_legacy_engines(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if any(mod.path == a or mod.path.startswith(a)
               for a in _LEGACY_ALLOWED):
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    _call_name(node) in _LEGACY_ENGINES:
                out.append(Violation(
                    "no-legacy-engine-construction", mod.path, node.lineno,
                    f"{_call_name(node)}(...) constructed outside serving/ "
                    "— use repro.serving.LLMEngine",
                ))
    return out


_DECODE_KERNELS = (
    "src/repro/kernels/decode_attention.py",
    "src/repro/kernels/paged_decode_attention.py",
)


@rule(
    "decode-relevance-shared",
    "the dense and paged decode kernels (one-pass and split-K paths alike) "
    "must gate units through decode_common.chunk_relevant and merge partials "
    "with decode_common.combine_split_states, not re-derive either locally",
)
def check_decode_relevance(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if mod.path not in _DECODE_KERNELS:
            continue
        counts = {"chunk_relevant": 0, "combine_split_states": 0}
        for ident, _line in _identifiers(mod.tree):
            if ident in counts:
                counts[ident] += 1
        if counts["chunk_relevant"] < 2:
            out.append(Violation(
                "decode-relevance-shared", mod.path, 1,
                "both the one-pass and split kernels must gate units via "
                "decode_common.chunk_relevant (fewer than 2 references)",
            ))
        if counts["combine_split_states"] < 1:
            out.append(Violation(
                "decode-relevance-shared", mod.path, 1,
                "split partials must merge via "
                "decode_common.combine_split_states",
            ))
        # Local re-derivation of the window edge (`length - window`): any
        # subtraction whose operands name `window` is relevance arithmetic
        # that belongs in decode_common.
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
                names = {i for i, _ in _identifiers(node)}
                if "window" in names:
                    out.append(Violation(
                        "decode-relevance-shared", mod.path, node.lineno,
                        "window-edge arithmetic re-derived locally — "
                        "relevance math lives in decode_common",
                    ))
    return out


@rule(
    "pallas-call-via-compat",
    "every pallas_call lives under src/repro/kernels/ and passes "
    "compiler_params=compat.tpu_compiler_params(...) so Mosaic scheduling "
    "hints survive JAX version bumps",
)
def check_pallas_call_compat(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    _call_name(node) == "pallas_call"):
                continue
            if not _in_dir(mod, "src/repro/kernels"):
                out.append(Violation(
                    "pallas-call-via-compat", mod.path, node.lineno,
                    "pallas_call outside src/repro/kernels/ — kernels are "
                    "the only layer that may talk to Pallas directly",
                ))
                continue
            cp = next((kw.value for kw in node.keywords
                       if kw.arg == "compiler_params"), None)
            ok = (isinstance(cp, ast.Call) and
                  _call_name(cp) == "tpu_compiler_params")
            if not ok:
                out.append(Violation(
                    "pallas-call-via-compat", mod.path, node.lineno,
                    "pallas_call without compiler_params="
                    "compat.tpu_compiler_params(...) — dimension semantics "
                    "must flow through the compat shim",
                ))
    return out


#: Decode-hot-loop functions in serving/: one step() must stay free of
#: host round-trips. ``LLMEngine._sync_scan`` is deliberately *not*
#: listed — with the fused ``lax.scan`` decode (ROADMAP item 3) it is the
#: sanctioned sync point, entered once per ``steps_per_sync`` tokens.
_HOT_LOOP_FNS = ("decode", "prepare_row", "_decode_tick", "fused_decode")
_HOST_SYNC_ATTRS = ("item", "block_until_ready")
_NUMPY_ALIASES = ("np", "numpy")


@rule(
    "no-host-sync-in-decode-hot-loop",
    "no .item() / np.asarray / block_until_ready inside serving/ decode "
    "hot-loop functions (decode, prepare_row, _decode_tick, fused_decode) "
    "— host syncs there serialize the NUMA-local pipeline; the only "
    "sanctioned sync point is LLMEngine._sync_scan, once per fused scan",
)
def check_host_sync(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if not _in_dir(mod, "src/repro/serving"):
            continue
        for node in ast.walk(mod.tree):
            if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name in _HOT_LOOP_FNS):
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call):
                    continue
                fn = sub.func
                if isinstance(fn, ast.Attribute) and \
                        fn.attr in _HOST_SYNC_ATTRS:
                    out.append(Violation(
                        "no-host-sync-in-decode-hot-loop", mod.path,
                        sub.lineno,
                        f".{fn.attr}() inside {node.name}() — host sync in "
                        "the decode hot loop",
                    ))
                elif (isinstance(fn, ast.Attribute) and
                      fn.attr == "asarray" and
                      isinstance(fn.value, ast.Name) and
                      fn.value.id in _NUMPY_ALIASES):
                    out.append(Violation(
                        "no-host-sync-in-decode-hot-loop", mod.path,
                        sub.lineno,
                        f"{fn.value.id}.asarray inside {node.name}() — "
                        "device->host copy in the decode hot loop",
                    ))
    return out


#: Serving functions on the per-tick path (PR 7): telemetry there may
#: only *use* pre-bound instruments, never register/look them up.
#: ``__init__`` is where binding happens; these are where it must not.
_OBS_HOT_FNS = ("step", "_decode_tick", "_sync_scan", "_flush",
                "_emit_lifecycle", "decode", "prepare_row",
                "fused_decode")
_OBS_REGISTRATION_CALLS = ("counter", "gauge", "histogram", "labels")


@rule(
    "obs-no-hot-loop-allocs",
    "serving per-tick functions (step/_decode_tick/_advance/_flush/"
    "_emit_lifecycle/decode/prepare_row) may not register or look up "
    "metric instruments (.counter/.gauge/.histogram/.labels) — bind them "
    "once at construction and call .inc()/.observe()/.set() on the bound "
    "object",
)
def check_obs_hot_loop_allocs(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if not _in_dir(mod, "src/repro/serving"):
            continue
        for node in ast.walk(mod.tree):
            if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name in _OBS_HOT_FNS):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr in _OBS_REGISTRATION_CALLS:
                    out.append(Violation(
                        "obs-no-hot-loop-allocs", mod.path, sub.lineno,
                        f".{sub.func.attr}(...) inside {node.name}() — "
                        "instrument registration/lookup in the decode hot "
                        "loop; pre-bind at construction",
                    ))
    return out


#: Cross-device collective primitives. Under the mesh-sharded serving
#: path (PR 9) the only sanctioned cross-device traffic is the split-K
#: combine and the sampler's logits reduction — everywhere else the
#: sharded decode must stay device-pure (GSPMD inserts what the
#: NamedShardings require; hand-written collectives in kernel bodies,
#: the scheduler, or the page pool would add fabric crossings the perf
#: model does not price).
_COLLECTIVE_IDENTS = ("psum", "psum_scatter", "all_gather", "ppermute",
                      "all_to_all", "pmean")
_COLLECTIVE_SCOPES = ("src/repro/kernels", "src/repro/serving",
                      "src/repro/cache")
_COLLECTIVE_ALLOWED = ("src/repro/kernels/decode_common.py",
                       "src/repro/serving/sampling.py")


@rule(
    "collectives-only-in-combine",
    "cross-device collectives (psum/all_gather/ppermute/...) may only "
    "appear in the sanctioned combine and sampling modules "
    "(kernels/decode_common.py, serving/sampling.py) — never in kernel "
    "bodies, the scheduler, or the page pool, which must stay "
    "device-pure under the head-sharded mesh",
)
def check_collectives(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if mod.path in _COLLECTIVE_ALLOWED:
            continue
        if not any(_in_dir(mod, d) for d in _COLLECTIVE_SCOPES):
            continue
        for ident, line in _identifiers(mod.tree):
            if ident in _COLLECTIVE_IDENTS:
                out.append(Violation(
                    "collectives-only-in-combine", mod.path, line,
                    f"{ident} outside the sanctioned combine/sampling "
                    "modules — cross-device traffic belongs in "
                    "decode_common's split combine or the sampler's "
                    "logits reduction",
                ))
    return out


#: Quantized-pool scale metadata (PR 10): ``k_scales`` / ``v_scales``
#: ride the page table — scalar-prefetch SMEM metadata indexed by page id
#: inside kernel bodies (src/repro/kernels/) and packed/scattered by the
#: quantization library (src/repro/cache/). Everywhere else they are
#: opaque cache-dict entries: serving code that *indexes* a bare
#: ``k_scales`` array or does arithmetic on one is re-deriving
#: dequantization outside the kernel, which silently diverges from what
#: the SMEM path actually computes.
_SCALE_NAMES = ("k_scales", "v_scales")
_SCALE_ALLOWED_DIRS = ("src/repro/kernels", "src/repro/cache")


@rule(
    "kv-scales-ride-page-table",
    "bare k_scales/v_scales arrays may only be indexed or used in "
    "arithmetic inside src/repro/kernels/ and src/repro/cache/ — "
    "everywhere else scale metadata is an opaque page-table payload "
    "(dict entries pass through; dequant math lives with the kernels)",
)
def check_kv_scales_opaque(modules: Sequence[Module]) -> List[Violation]:
    out: List[Violation] = []
    for mod in modules:
        if any(_in_dir(mod, d) for d in _SCALE_ALLOWED_DIRS):
            continue

        def bad(node: ast.AST) -> Optional[str]:
            if isinstance(node, ast.Name) and node.id in _SCALE_NAMES:
                return node.id
            return None

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Subscript):
                name = bad(node.value)
                if name:
                    out.append(Violation(
                        "kv-scales-ride-page-table", mod.path, node.lineno,
                        f"{name}[...] outside kernels/cache — scale "
                        "metadata is opaque page-table payload here",
                    ))
            elif isinstance(node, ast.BinOp):
                name = bad(node.left) or bad(node.right)
                if name:
                    out.append(Violation(
                        "kv-scales-ride-page-table", mod.path, node.lineno,
                        f"arithmetic on {name} outside kernels/cache — "
                        "dequantization lives with the kernel SMEM path",
                    ))
    return out


# --- driver -------------------------------------------------------------------


def repo_root() -> pathlib.Path:
    """The repo checkout that owns the installed ``repro`` package."""
    import repro

    # src/repro/__init__.py -> src/repro -> src -> repo root
    return pathlib.Path(repro.__file__).resolve().parents[2]


def collect_modules(root: pathlib.Path) -> List[Module]:
    mods: List[Module] = []
    for sub in SCAN_DIRS:
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            source = path.read_text()
            try:
                tree = ast.parse(source, filename=rel)
            except SyntaxError as e:  # pragma: no cover - broken tree
                raise SystemExit(f"{rel}: syntax error while linting: {e}")
            mods.append(Module(rel, source, tree))
    return mods


def run_rules(
    root: Optional[pathlib.Path] = None,
    rules: Optional[Sequence[str]] = None,
) -> List[Violation]:
    """Lint the tree at ``root`` (default: the live repo) and return
    every violation from the selected rules."""
    modules = collect_modules(root or repo_root())
    return _apply(modules, rules)


def lint_source(
    source: str,
    virtual_path: str,
    rules: Optional[Sequence[str]] = None,
) -> List[Violation]:
    """Lint a source snippet as if it lived at ``virtual_path``.

    Used by the rule-coverage tests to prove each rule still fires on a
    known-bad fixture without planting bad files in the tree.
    """
    tree = ast.parse(source, filename=virtual_path)
    return _apply([Module(virtual_path, source, tree)], rules)


def _apply(
    modules: Sequence[Module],
    rules: Optional[Sequence[str]],
) -> List[Violation]:
    selected = list(RULES) if rules is None else list(rules)
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise KeyError(f"unknown rule(s): {unknown}; have {sorted(RULES)}")
    out: List[Violation] = []
    for name in selected:
        out.extend(RULES[name].check(modules))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="NUMA-contract linter (AST rule registry)",
    )
    parser.add_argument("--strict", action="store_true",
                        help="advisory rules fail the run too")
    parser.add_argument("--root", type=pathlib.Path, default=None,
                        help="repo root to scan (default: the live repo)")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="NAME", help="run only this rule "
                        "(repeatable); default: all")
    parser.add_argument("--list", action="store_true",
                        help="list registered rules and exit")
    args = parser.parse_args(argv)

    if args.list:
        for r in RULES.values():
            tag = " (advisory)" if r.advisory else ""
            print(f"{r.name}{tag}\n    {r.description}")
        return 0

    violations = run_rules(args.root, args.rule)
    fatal = 0
    for v in violations:
        advisory = RULES[v.rule].advisory and not args.strict
        stream = sys.stdout if advisory else sys.stderr
        prefix = "warning" if advisory else "error"
        print(f"{prefix}: {v}", file=stream)
        fatal += 0 if advisory else 1
    checked = len(RULES) if args.rule is None else len(args.rule)
    if not violations:
        print(f"repro.analysis: {checked} rule(s) clean")
    return 1 if fatal else 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
