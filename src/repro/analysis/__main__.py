"""``python -m repro.analysis`` — run the NUMA-contract linter."""

import sys

from repro.analysis.lint import main

if __name__ == "__main__":
    sys.exit(main())
