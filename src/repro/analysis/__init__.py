"""Static analysis & sanitizers for the repo's NUMA contracts.

The paper's modeled wins rest on invariants the code can only promise:
head-first mappings stay inside a NUMA domain, split-K ranges are
domain-pure under the head-major pool, every kernel routes through the one
versioned-API shim, and the page pool's refcount/COW discipline is never
violated. This package turns those promises into checked contracts, three
layers deep:

  * :mod:`repro.analysis.lint` — AST-based NUMA-contract linter. A rule
    registry of AST visitors subsumes (and extends) the grep scans that
    used to live copy-pasted inside three test files. Runnable as
    ``python -m repro.analysis [--strict]``; CI runs it ahead of tier-1.
  * :mod:`repro.analysis.pool_sanitizer` — a shadow state machine
    (FREE/OWNED/SHARED) over :class:`repro.cache.pool.PagePool` that
    detects double-free, use-after-release, writes through the reserved
    null page, COW violations, and refcount leaks. Attached as an autouse
    pytest fixture across the scheduler/serving/paged-cache suites.
  * :mod:`repro.analysis.access_trace` — domain-purity access tracer: it
    replays the *same* BlockSpec index maps the Pallas kernels hand to
    ``pallas_call`` over a concrete page table and asserts, per grid
    cell, the domain-purity/locality claims that
    ``cache.layout.split_ranges_domain_aligned`` and the perf model
    assume analytically. Wired into the ``--smoke`` CI path so a
    cross-domain straddle fails CI instead of silently invalidating the
    modeled speedups.
"""

from repro.analysis.lint import (  # noqa: F401
    RULES,
    Violation,
    lint_source,
    repo_root,
    run_rules,
)
