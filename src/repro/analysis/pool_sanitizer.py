"""Shadow state machine over :class:`repro.cache.pool.PagePool`.

ASan for the paged KV cache: a :class:`ShadowPool` attaches to a live pool
instance and mirrors every page's lifecycle through an independent
FREE → OWNED → SHARED state machine, checking each transition *before* the
real pool mutates and cross-checking the shadow refcounts against the
pool's after every operation. It catches the misuse classes the pool's own
asserts cannot see from inside one call:

  * **double free** — a ``decref``/``release`` on a page the shadow already
    holds at refcount zero,
  * **use-after-release** — appending to / forking / increffing a released
    sequence or freed page, or (via :meth:`check_tables`) a live engine
    page table still pointing at a freed page,
  * **null-page writes** — a token append that would land data in the
    reserved page 0 (the unconditional-scatter sink; writing real data
    there corrupts every inactive row),
  * **COW violations** — an append into a ``refcount > 1`` (SHARED) tail
    that does not come back with the ``(src, dst)`` copy instruction,
  * **refcount desync / leaks** — the shadow and the pool disagreeing, or
    :meth:`check_leaks` finding references nobody claims at teardown.

Attachment patches *instance* attributes only (the class is untouched), so
the pool's own compound operations (``allocate_sequence``, ``fork``,
``release``) route their internal ``self.alloc``/``incref``/``decref``
calls through the shadow automatically. ``tests/conftest.py`` attaches a
shadow to every pool constructed in the scheduler/serving/paged-cache
suites, so the whole tier-1 serving surface runs sanitized.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.cache.pool import (
    NULL_PAGE,
    PagePool,
    PoolError,
    SequencePages,
)

__all__ = [
    "CowViolationError",
    "DoubleFreeError",
    "NullPageWriteError",
    "PoolSanitizerError",
    "ShadowDesyncError",
    "ShadowPool",
    "UseAfterReleaseError",
    "attach",
]

# Shadow page states (derived: FREE rc==0, OWNED rc==1, SHARED rc>1).
FREE = "FREE"
OWNED = "OWNED"
SHARED = "SHARED"


class PoolSanitizerError(PoolError):
    """Base class: the shadow machine observed an illegal transition."""


class DoubleFreeError(PoolSanitizerError):
    pass


class UseAfterReleaseError(PoolSanitizerError):
    pass


class NullPageWriteError(PoolSanitizerError):
    pass


class CowViolationError(PoolSanitizerError):
    pass


class ShadowDesyncError(PoolSanitizerError):
    """Shadow and pool refcounts disagree — some path mutated refcounts
    without going through the instrumented primitives."""


class ShadowPool:
    """Attach with :func:`attach` (or construct directly); detach with
    :meth:`detach`. While attached, every pool operation is validated."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        # Mirror whatever state the pool is in at attach time.
        self._shadow: List[int] = list(pool._refcount)
        self.ops = 0  # transitions observed (for test assertions)
        self._orig = {
            "alloc": pool.alloc,
            "incref": pool.incref,
            "decref": pool.decref,
            "append_token": pool.append_token,
        }
        pool.alloc = self._alloc
        pool.incref = self._incref
        pool.decref = self._decref
        pool.append_token = self._append_token
        self._attached = True

    # -- state queries ------------------------------------------------------

    def state(self, pid: int) -> str:
        rc = self._shadow[pid]
        if pid == NULL_PAGE:
            return SHARED  # permanently pinned, never writable
        return FREE if rc == 0 else (OWNED if rc == 1 else SHARED)

    # -- instrumented primitives -------------------------------------------

    def _alloc(self) -> int:
        pid = self._orig["alloc"]()  # may raise OutOfPages: no shadow change
        if self._shadow[pid] != 0:
            raise ShadowDesyncError(
                f"pool allocated page {pid} the shadow holds at "
                f"rc={self._shadow[pid]}"
            )
        self._shadow[pid] = 1
        self._after()
        return pid

    def _incref(self, pid: int) -> None:
        if pid != NULL_PAGE and self._shadow[pid] <= 0:
            raise UseAfterReleaseError(f"incref on FREE page {pid}")
        self._orig["incref"](pid)
        if pid != NULL_PAGE:
            self._shadow[pid] += 1
        self._after()

    def _decref(self, pid: int) -> bool:
        if pid != NULL_PAGE and self._shadow[pid] <= 0:
            raise DoubleFreeError(f"decref on FREE page {pid}")
        freed = self._orig["decref"](pid)
        if pid != NULL_PAGE:
            self._shadow[pid] -= 1
        self._after()
        return freed

    def _append_token(
        self, seq: SequencePages
    ) -> Tuple[int, int, Optional[Tuple[int, int]]]:
        if seq.released:
            raise UseAfterReleaseError(
                "append_token on a released sequence"
            )
        for pid in seq.pages:
            if pid != NULL_PAGE and self._shadow[pid] <= 0:
                raise UseAfterReleaseError(
                    f"append_token on a sequence holding FREE page {pid}"
                )
        opens_page = seq.length % self.pool.page_size == 0
        tail = None if opens_page else seq.tail_page()
        if tail == NULL_PAGE:
            raise NullPageWriteError(
                "append would write a token into the reserved null page"
            )
        shared_tail = tail is not None and self.state(tail) == SHARED
        pid, off, cow = self._orig["append_token"](seq)
        if pid == NULL_PAGE:
            raise NullPageWriteError(
                "append_token landed in the reserved null page"
            )
        if shared_tail and cow != (tail, pid):
            raise CowViolationError(
                f"append into SHARED page {tail} returned cow={cow}; "
                f"expected ({tail}, {pid}) copy instruction"
            )
        if not shared_tail and cow is not None:
            raise CowViolationError(
                f"spurious COW {cow} on exclusive/new page append"
            )
        self._after()
        return pid, off, cow

    # -- cross-checks -------------------------------------------------------

    def _after(self) -> None:
        self.ops += 1
        self.assert_sync()

    def assert_sync(self) -> None:
        """Raise :class:`ShadowDesyncError` unless shadow and pool agree
        on every refcount. Cheap (one list compare) — runs after every
        instrumented op and again at fixture teardown."""
        if self._shadow != self.pool._refcount:
            bad = {
                pid: (self.pool._refcount[pid], self._shadow[pid])
                for pid in range(self.pool.num_pages)
                if self.pool._refcount[pid] != self._shadow[pid]
            }
            raise ShadowDesyncError(
                f"shadow/pool refcount mismatch (pool, shadow): {bad}"
            )

    def check_tables(self, tables: Iterable[Sequence[int]]) -> None:
        """Use-after-release sweep: every page id a live table references
        must be allocated in the shadow (the null page is the sanctioned
        placeholder for inactive rows)."""
        for table in tables:
            for pid in table:
                pid = int(pid)
                if pid != NULL_PAGE and self._shadow[pid] <= 0:
                    raise UseAfterReleaseError(
                        f"live page table references FREE page {pid}"
                    )

    def check_leaks(
        self, live_refs: Optional[Dict[int, int]] = None
    ) -> None:
        """Shadow-side leak audit: sync with the pool, then delegate to
        :meth:`PagePool.check_leaks`."""
        if self._shadow != self.pool._refcount:
            self._after()  # raises ShadowDesyncError with detail
        self.pool.check_leaks(live_refs)

    def detach(self) -> None:
        """Restore the pool's unwrapped methods (idempotent)."""
        if not self._attached:
            return
        for name in self._orig:
            # The originals are bound methods; deleting the instance attr
            # falls back to the class implementation, which is identical.
            try:
                delattr(self.pool, name)
            except AttributeError:
                pass
        self._attached = False


def attach(pool: PagePool) -> ShadowPool:
    """Instrument ``pool`` in place; returns the shadow for queries and
    teardown checks."""
    return ShadowPool(pool)
