"""Shadow state machine over :class:`repro.cache.pool.PagePool`.

ASan for the paged KV cache: a :class:`ShadowPool` attaches to a live pool
instance and mirrors every page's lifecycle through an independent
FREE → OWNED → SHARED state machine, checking each transition *before* the
real pool mutates and cross-checking the shadow refcounts against the
pool's after every operation. It catches the misuse classes the pool's own
asserts cannot see from inside one call:

  * **double free** — a ``decref``/``release`` on a page the shadow already
    holds at refcount zero,
  * **use-after-release** — appending to / forking / increffing a released
    sequence or freed page, or (via :meth:`check_tables`) a live engine
    page table still pointing at a freed page,
  * **null-page writes** — a token append that would land data in the
    reserved page 0 (the unconditional-scatter sink; writing real data
    there corrupts every inactive row),
  * **COW violations** — an append into a ``refcount > 1`` (SHARED) tail
    that does not come back with the ``(src, dst)`` copy instruction,
  * **refcount desync / leaks** — the shadow and the pool disagreeing, or
    :meth:`check_leaks` finding references nobody claims at teardown.

Attachment patches *instance* attributes only (the class is untouched), so
the pool's own compound operations (``allocate_sequence``, ``fork``,
``release``) route their internal ``self.alloc``/``incref``/``decref``
calls through the shadow automatically. ``tests/conftest.py`` attaches a
shadow to every pool constructed in the scheduler/serving/paged-cache
suites, so the whole tier-1 serving surface runs sanitized.

:class:`ShadowTier` extends the same idea one tier down: it attaches to a
:class:`repro.cache.tier.HostPageStore` (and, bound, the device
:class:`~repro.cache.prefix.PrefixCache` in front of it) and mirrors each
chain hash through a DEVICE / HOST residency machine — residency is
exclusive by construction, and the shadow catches the violations:

  * **double demote** — admitting a hash that is already host-resident,
  * **promote-after-free** — taking a payload the host tier no longer
    holds (LRU-evicted, drained, or already promoted),
  * **stale device read** — a device prefix lookup returning (or an
    insert creating) an entry for a hash whose page was demoted — the
    device copy should have been dropped at demotion.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.cache.pool import (
    NULL_PAGE,
    PagePool,
    PoolError,
    SequencePages,
)

__all__ = [
    "CowViolationError",
    "DoubleDemoteError",
    "DoubleFreeError",
    "NullPageWriteError",
    "PoolSanitizerError",
    "PromoteAfterFreeError",
    "ShadowDesyncError",
    "ShadowPool",
    "ShadowTier",
    "StaleDeviceReadError",
    "UseAfterReleaseError",
    "attach",
    "attach_tier",
]

# Shadow page states (derived: FREE rc==0, OWNED rc==1, SHARED rc>1).
FREE = "FREE"
OWNED = "OWNED"
SHARED = "SHARED"

# Shadow tier residency states per chain hash (absent = never seen /
# gone): DEVICE = prefix-cache entry holds a device page; HOST = demoted
# payload lives in the host store.
DEVICE = "DEVICE"
HOST = "HOST"


class PoolSanitizerError(PoolError):
    """Base class: the shadow machine observed an illegal transition."""


class DoubleFreeError(PoolSanitizerError):
    pass


class UseAfterReleaseError(PoolSanitizerError):
    pass


class NullPageWriteError(PoolSanitizerError):
    pass


class CowViolationError(PoolSanitizerError):
    pass


class ShadowDesyncError(PoolSanitizerError):
    """Shadow and pool refcounts disagree — some path mutated refcounts
    without going through the instrumented primitives."""


class DoubleDemoteError(PoolSanitizerError):
    """Demotion of a hash that is already host-resident — the device copy
    was never promoted back, so something demoted the same page twice."""


class PromoteAfterFreeError(PoolSanitizerError):
    """Promotion (take) of a hash the host tier no longer holds — it was
    LRU-evicted, drained, or already promoted."""


class StaleDeviceReadError(PoolSanitizerError):
    """A device prefix-cache entry exists (or was read) for a hash whose
    page was demoted host-side — the device copy should have been dropped
    at demotion; residency is exclusive."""


class ShadowPool:
    """Attach with :func:`attach` (or construct directly); detach with
    :meth:`detach`. While attached, every pool operation is validated."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        # Mirror whatever state the pool is in at attach time.
        self._shadow: List[int] = list(pool._refcount)
        self.ops = 0  # transitions observed (for test assertions)
        self._orig = {
            "alloc": pool.alloc,
            "incref": pool.incref,
            "decref": pool.decref,
            "append_token": pool.append_token,
        }
        pool.alloc = self._alloc
        pool.incref = self._incref
        pool.decref = self._decref
        pool.append_token = self._append_token
        self._attached = True

    # -- state queries ------------------------------------------------------

    def state(self, pid: int) -> str:
        rc = self._shadow[pid]
        if pid == NULL_PAGE:
            return SHARED  # permanently pinned, never writable
        return FREE if rc == 0 else (OWNED if rc == 1 else SHARED)

    # -- instrumented primitives -------------------------------------------

    def _alloc(self) -> int:
        pid = self._orig["alloc"]()  # may raise OutOfPages: no shadow change
        if self._shadow[pid] != 0:
            raise ShadowDesyncError(
                f"pool allocated page {pid} the shadow holds at "
                f"rc={self._shadow[pid]}"
            )
        self._shadow[pid] = 1
        self._after()
        return pid

    def _incref(self, pid: int) -> None:
        if pid != NULL_PAGE and self._shadow[pid] <= 0:
            raise UseAfterReleaseError(f"incref on FREE page {pid}")
        self._orig["incref"](pid)
        if pid != NULL_PAGE:
            self._shadow[pid] += 1
        self._after()

    def _decref(self, pid: int) -> bool:
        if pid != NULL_PAGE and self._shadow[pid] <= 0:
            raise DoubleFreeError(f"decref on FREE page {pid}")
        freed = self._orig["decref"](pid)
        if pid != NULL_PAGE:
            self._shadow[pid] -= 1
        self._after()
        return freed

    def _append_token(
        self, seq: SequencePages
    ) -> Tuple[int, int, Optional[Tuple[int, int]]]:
        if seq.released:
            raise UseAfterReleaseError(
                "append_token on a released sequence"
            )
        for pid in seq.pages:
            if pid != NULL_PAGE and self._shadow[pid] <= 0:
                raise UseAfterReleaseError(
                    f"append_token on a sequence holding FREE page {pid}"
                )
        opens_page = seq.length % self.pool.page_size == 0
        tail = None if opens_page else seq.tail_page()
        if tail == NULL_PAGE:
            raise NullPageWriteError(
                "append would write a token into the reserved null page"
            )
        shared_tail = tail is not None and self.state(tail) == SHARED
        pid, off, cow = self._orig["append_token"](seq)
        if pid == NULL_PAGE:
            raise NullPageWriteError(
                "append_token landed in the reserved null page"
            )
        if shared_tail and cow != (tail, pid):
            raise CowViolationError(
                f"append into SHARED page {tail} returned cow={cow}; "
                f"expected ({tail}, {pid}) copy instruction"
            )
        if not shared_tail and cow is not None:
            raise CowViolationError(
                f"spurious COW {cow} on exclusive/new page append"
            )
        self._after()
        return pid, off, cow

    # -- cross-checks -------------------------------------------------------

    def _after(self) -> None:
        self.ops += 1
        self.assert_sync()

    def assert_sync(self) -> None:
        """Raise :class:`ShadowDesyncError` unless shadow and pool agree
        on every refcount. Cheap (one list compare) — runs after every
        instrumented op and again at fixture teardown."""
        if self._shadow != self.pool._refcount:
            bad = {
                pid: (self.pool._refcount[pid], self._shadow[pid])
                for pid in range(self.pool.num_pages)
                if self.pool._refcount[pid] != self._shadow[pid]
            }
            raise ShadowDesyncError(
                f"shadow/pool refcount mismatch (pool, shadow): {bad}"
            )

    def check_tables(self, tables: Iterable[Sequence[int]]) -> None:
        """Use-after-release sweep: every page id a live table references
        must be allocated in the shadow (the null page is the sanctioned
        placeholder for inactive rows)."""
        for table in tables:
            for pid in table:
                pid = int(pid)
                if pid != NULL_PAGE and self._shadow[pid] <= 0:
                    raise UseAfterReleaseError(
                        f"live page table references FREE page {pid}"
                    )

    def check_leaks(
        self, live_refs: Optional[Dict[int, int]] = None
    ) -> None:
        """Shadow-side leak audit: sync with the pool, then delegate to
        :meth:`PagePool.check_leaks`."""
        if self._shadow != self.pool._refcount:
            self._after()  # raises ShadowDesyncError with detail
        self.pool.check_leaks(live_refs)

    def detach(self) -> None:
        """Restore the pool's unwrapped methods (idempotent)."""
        if not self._attached:
            return
        for name in self._orig:
            # The originals are bound methods; deleting the instance attr
            # falls back to the class implementation, which is identical.
            try:
                delattr(self.pool, name)
            except AttributeError:
                pass
        self._attached = False


def attach(pool: PagePool) -> ShadowPool:
    """Instrument ``pool`` in place; returns the shadow for queries and
    teardown checks."""
    return ShadowPool(pool)


class ShadowTier:
    """Residency state machine over a device↔host KV tier: instruments a
    :class:`repro.cache.tier.HostPageStore` (and, via :meth:`bind_prefix`,
    the device :class:`~repro.cache.prefix.PrefixCache` in front of it),
    mirroring each chain hash through DEVICE / HOST / gone. Instance
    attributes only, same contract as :class:`ShadowPool`."""

    def __init__(self, host):
        self.host = host
        self._state: Dict[bytes, str] = {}
        self.prefix = None
        self.ops = 0
        self._orig = {
            "admit": host.admit,
            "take": host.take,
            "discard": host.discard,
            "drain": host.drain,
        }
        host.admit = self._admit
        host.take = self._take
        host.discard = self._discard
        host.drain = self._drain
        self._prefix_orig: Dict[str, object] = {}
        self._attached = True

    def bind_prefix(self, prefix) -> "ShadowTier":
        """Also instrument the device prefix cache paired with this host
        store, so stale device reads (and inserts) of demoted hashes are
        caught at the device side too."""
        self.prefix = prefix
        self._prefix_orig = {
            "lookup": prefix.lookup,
            "insert": prefix.insert,
        }
        prefix.lookup = self._lookup
        prefix.insert = self._insert
        return self

    def state(self, h: bytes) -> Optional[str]:
        return self._state.get(h)

    # -- instrumented host-store primitives ---------------------------------

    def _admit(self, h, payload) -> bool:
        self.ops += 1
        if self._state.get(h) == HOST:
            raise DoubleDemoteError(
                f"demote of hash {h!r}, which is already host-resident"
            )
        stored = self._orig["admit"](h, payload)
        if stored:
            self._state[h] = HOST
        # Mirror host-LRU overflow: hashes the admit pushed out are gone.
        for k in [k for k, s in self._state.items()
                  if s == HOST and k not in self.host]:
            del self._state[k]
        return stored

    def _take(self, h):
        self.ops += 1
        if self._state.get(h) != HOST:
            raise PromoteAfterFreeError(
                f"promote (take) of hash {h!r}, which the host tier does "
                f"not hold (state={self._state.get(h)})"
            )
        payload = self._orig["take"](h)
        self._state.pop(h, None)
        return payload

    def _discard(self, h) -> bool:
        self.ops += 1
        dropped = self._orig["discard"](h)
        if dropped:
            self._state.pop(h, None)
        return dropped

    def _drain(self) -> int:
        self.ops += 1
        n = self._orig["drain"]()
        self._state = {
            k: s for k, s in self._state.items() if s != HOST
        }
        return n

    # -- instrumented device prefix cache -----------------------------------

    def _lookup(self, hashes, touch: bool = True):
        self.ops += 1
        out = self._prefix_orig["lookup"](hashes, touch=touch)
        for h in list(hashes)[: len(out)]:
            if self._state.get(h) == HOST:
                raise StaleDeviceReadError(
                    f"device prefix lookup matched hash {h!r}, whose page "
                    f"was demoted host-side"
                )
        return out

    def _insert(self, hashes, pages):
        self.ops += 1
        for h in hashes:
            if self._state.get(h) == HOST:
                raise StaleDeviceReadError(
                    f"device prefix insert of hash {h!r} while its payload "
                    f"is host-resident; promote (take) or discard it first"
                )
        added = self._prefix_orig["insert"](hashes, pages)
        for h in hashes:
            self._state[h] = DEVICE
        return added

    def detach(self) -> None:
        """Restore the unwrapped methods (idempotent)."""
        if not self._attached:
            return
        for name in self._orig:
            try:
                delattr(self.host, name)
            except AttributeError:
                pass
        for name in self._prefix_orig:
            try:
                delattr(self.prefix, name)
            except AttributeError:
                pass
        self._attached = False


def attach_tier(host, prefix=None) -> ShadowTier:
    """Instrument a host page store (and optionally its device prefix
    cache) in place; returns the shadow tier for queries and teardown."""
    shadow = ShadowTier(host)
    if prefix is not None:
        shadow.bind_prefix(prefix)
    return shadow
