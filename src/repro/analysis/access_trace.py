"""Domain-purity access tracer for the paged / split-K attention kernels.

The perf model's NUMA claims are *analytic*: ``cache.layout`` proves that
``decode_split_ranges`` boundaries are domain-pure under the head-major
pool by reasoning over logical page indices. The kernels, however, touch
whatever their **BlockSpec index maps** say — a refactor that changes a
``lambda`` inside ``pallas_call`` could silently break the co-location
story while every numeric test still passes (attention output does not
depend on where a page lives).

This module closes that gap: it replays the *exact* index-map functions
the kernels export (``paged_kv_index_map`` / ``split_kv_index_map`` /
``prefix_page_index_map`` / ``split_chunk_index_map`` — module-level in
the kernel files precisely so tracer and ``pallas_call`` cannot diverge)
over a concrete page table, records which physical page every grid cell
DMAs, and asserts:

  * **domain purity** — each cell's *live* fetches (the ones whose compute
    actually runs; clamped tail-overhang DMAs are recorded but skipped by
    ``decode_common.chunk_relevant``, same as in the kernel) stay inside
    one memory domain;
  * **domain locality** — under ``HEAD_ALIGNED`` each live fetch lands in
    the very domain that executes the cell (``domain_of_head``);
  * **range consistency** — the split-K cells' live logical pages are
    exactly the ``decode_split_ranges`` partition the plan layer reasons
    about, so model and kernel agree on who reads what.

Runs everywhere the interpret path runs (pure host arithmetic — no Pallas
launch needed); the ``--smoke`` CI step traces a ``num_splits > 1`` paged
plan on every push.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.cache import layout as layout_lib
from repro.kernels import decode_common
from repro.kernels.decode_attention import split_chunk_index_map
from repro.kernels.paged_decode_attention import (
    paged_kv_index_map,
    split_kv_index_map,
)
from repro.kernels.paged_prefill_attention import prefix_page_index_map

__all__ = [
    "AccessTrace",
    "CellTrace",
    "DomainPurityError",
    "trace_dense_split_decode",
    "trace_paged_decode",
    "trace_paged_prefill",
    "trace_plan",
]


class DomainPurityError(AssertionError):
    """A grid cell's live page fetches straddle NUMA domains (or miss the
    cell's own domain) — the co-location claim the perf model banks on
    does not hold for this (plan, page table)."""


@dataclasses.dataclass(frozen=True)
class CellTrace:
    """What one grid cell touches."""

    cell: Tuple[int, ...]        # grid coordinates: (b, h) or (b, h, s)
    head: int
    cell_domain: int             # domain executing the cell (head-first grid)
    touched: Tuple[int, ...]     # every physical page the index map DMAs
    live: Tuple[int, ...]        # the subset whose compute actually runs
    live_logical: Tuple[int, ...]  # logical page/chunk indices of `live`

    def live_domains(
        self, policy: str, num_kv_heads: int, num_domains: int
    ) -> Tuple[int, ...]:
        return tuple(sorted({
            layout_lib.domain_of_page(
                pid, self.head, policy, num_kv_heads, num_domains)
            for pid in self.live
        }))


@dataclasses.dataclass
class AccessTrace:
    kernel: str
    policy: str
    num_kv_heads: int
    num_domains: int
    cells: List[CellTrace]

    @property
    def touched_pages(self) -> int:
        return sum(len(c.touched) for c in self.cells)

    @property
    def live_pages(self) -> int:
        return sum(len(c.live) for c in self.cells)

    def assert_domain_pure(self) -> "AccessTrace":
        """Every cell's live fetches read from at most one domain."""
        for c in self.cells:
            doms = c.live_domains(
                self.policy, self.num_kv_heads, self.num_domains)
            if len(doms) > 1:
                raise DomainPurityError(
                    f"{self.kernel}: cell {c.cell} (head {c.head}) reads "
                    f"pages {c.live} from domains {doms} under "
                    f"{self.policy!r} — a split straddles the fabric"
                )
        return self

    def assert_domain_local(self) -> "AccessTrace":
        """Every cell's live fetches read the cell's *own* domain — the
        stronger property HEAD_ALIGNED promises (purity plus locality)."""
        self.assert_domain_pure()
        for c in self.cells:
            doms = c.live_domains(
                self.policy, self.num_kv_heads, self.num_domains)
            if doms and doms != (c.cell_domain,):
                raise DomainPurityError(
                    f"{self.kernel}: cell {c.cell} (domain "
                    f"{c.cell_domain}) reads pages {c.live} homed in "
                    f"domain {doms[0]} under {self.policy!r} — pure but "
                    "not local"
                )
        return self


def _pt_lookup(pt: np.ndarray, idx) -> int:
    # Index maps return jnp scalars (jnp.minimum); concretize for numpy.
    return int(np.asarray(idx))


def trace_paged_decode(
    page_table: np.ndarray,
    lengths: Sequence[int],
    *,
    num_kv_heads: int,
    page_size: int,
    num_splits: int = 1,
    window: Optional[int] = None,
    policy: str = layout_lib.HEAD_ALIGNED,
    num_domains: int = 2,
) -> AccessTrace:
    """Replay the paged decode kernel's K/V index map (one-pass or
    split-K) over ``page_table``/``lengths`` and return the per-cell
    access trace. ``num_splits > 1`` additionally cross-checks every
    cell's live logical pages against ``decode_split_ranges`` — the same
    partition ``split_ranges_domain_aligned`` certifies analytically."""
    pt = np.asarray(page_table, dtype=np.int64)
    lens = np.asarray(lengths, dtype=np.int64)
    b, max_pages = pt.shape
    ranges = layout_lib.decode_split_ranges(max_pages, num_splits)
    cells: List[CellTrace] = []

    def live_at(batch: int, p_logical: int) -> bool:
        return bool(decode_common.chunk_relevant(
            p_logical * page_size, page_size, int(lens[batch]), window))

    if len(ranges) == 1:
        kernel = "paged_flash_decode"
        for b_ in range(b):
            for h_ in range(num_kv_heads):
                touched, live, logical = [], [], []
                for p_ in range(max_pages):
                    _, pid, _, _ = paged_kv_index_map(b_, h_, p_, pt, lens)
                    pid = _pt_lookup(pt, pid)
                    touched.append(pid)
                    if live_at(b_, p_):
                        live.append(pid)
                        logical.append(p_)
                cells.append(CellTrace(
                    cell=(b_, h_), head=h_,
                    cell_domain=layout_lib.domain_of_head(
                        h_, num_kv_heads, num_domains),
                    touched=tuple(touched), live=tuple(live),
                    live_logical=tuple(logical),
                ))
    else:
        kernel = "paged_flash_decode_split"
        pps = ranges[0][1] - ranges[0][0]
        kv_index = split_kv_index_map(pps, max_pages)
        for b_ in range(b):
            for h_ in range(num_kv_heads):
                for s_, (start, end) in enumerate(ranges):
                    touched, live, logical = [], [], []
                    for j_ in range(pps):
                        _, pid, _, _ = kv_index(b_, h_, s_, j_, pt, lens)
                        pid = _pt_lookup(pt, pid)
                        touched.append(pid)
                        p_global = s_ * pps + j_
                        if p_global < max_pages and live_at(b_, p_global):
                            live.append(pid)
                            logical.append(p_global)
                    # The kernel's live walk must be exactly this split's
                    # slice of the plan-layer partition, truncated to the
                    # sequence's live pages (the relevance predicate).
                    live_pages = -(-int(lens[b_]) // page_size)
                    expect = tuple(
                        p for p in range(start, min(end, max_pages))
                        if live_at(b_, p)
                    )
                    if tuple(logical) != expect:
                        raise DomainPurityError(
                            f"{kernel}: cell {(b_, h_, s_)} walks logical "
                            f"pages {tuple(logical)}; decode_split_ranges "
                            f"says {expect} (live={live_pages})"
                        )
                    cells.append(CellTrace(
                        cell=(b_, h_, s_), head=h_,
                        cell_domain=layout_lib.domain_of_head(
                            h_, num_kv_heads, num_domains),
                        touched=tuple(touched), live=tuple(live),
                        live_logical=tuple(logical),
                    ))
    return AccessTrace(
        kernel=kernel, policy=policy, num_kv_heads=num_kv_heads,
        num_domains=num_domains, cells=cells,
    )


def trace_paged_prefill(
    page_table: np.ndarray,
    prefix_lens: Sequence[int],
    *,
    num_kv_heads: int,
    page_size: int,
    num_tail: int = 1,
    policy: str = layout_lib.HEAD_ALIGNED,
    num_domains: int = 2,
) -> AccessTrace:
    """Replay the paged prefill kernel's prefix-page index map: grid
    (b, hkv, mp + num_tail). Steps past the prefix (the dense-tail sweep)
    clamp to the last table slot — recorded as touched, never live."""
    pt = np.asarray(page_table, dtype=np.int64)
    plens = np.asarray(prefix_lens, dtype=np.int64)
    b, mp = pt.shape
    page_idx = prefix_page_index_map(mp)
    cells: List[CellTrace] = []
    for b_ in range(b):
        live_prefix = -(-int(plens[b_]) // page_size)
        for h_ in range(num_kv_heads):
            touched, live, logical = [], [], []
            for s_ in range(mp + num_tail):
                _, pid, _, _ = page_idx(b_, h_, s_, pt, plens, None)
                pid = _pt_lookup(pt, pid)
                touched.append(pid)
                if s_ < live_prefix:
                    live.append(pid)
                    logical.append(s_)
            cells.append(CellTrace(
                cell=(b_, h_), head=h_,
                cell_domain=layout_lib.domain_of_head(
                    h_, num_kv_heads, num_domains),
                touched=tuple(touched), live=tuple(live),
                live_logical=tuple(logical),
            ))
    return AccessTrace(
        kernel="paged_prefill", policy=policy, num_kv_heads=num_kv_heads,
        num_domains=num_domains, cells=cells,
    )


def trace_dense_split_decode(
    lengths: Sequence[int],
    *,
    capacity: int,
    chunk: int,
    num_kv_heads: int,
    num_splits: int,
    window: Optional[int] = None,
    num_domains: int = 2,
) -> AccessTrace:
    """Dense split-K analogue: the KV stripe has no page table (logical
    chunk == physical chunk), so the trace proves the index map walks
    exactly the ``decode_split_ranges`` partition with the tail overhang
    clamped. Domains follow the head-first grid (dense stripes are sharded
    by head), so the HEAD_ALIGNED checks apply unchanged."""
    lens = np.asarray(lengths, dtype=np.int64)
    num_chunks = -(-capacity // chunk)
    ranges = layout_lib.decode_split_ranges(num_chunks, num_splits)
    if len(ranges) < 2:
        raise ValueError("dense split trace needs an effective split > 1")
    cps = ranges[0][1] - ranges[0][0]
    kv_index = split_chunk_index_map(cps, num_chunks)
    cells: List[CellTrace] = []
    for b_ in range(len(lens)):
        for h_ in range(num_kv_heads):
            for s_, (start, end) in enumerate(ranges):
                touched, live, logical = [], [], []
                for j_ in range(cps):
                    _, _, c_idx, _ = kv_index(b_, h_, s_, j_)
                    c_idx = int(np.asarray(c_idx))
                    touched.append(c_idx)
                    c_global = s_ * cps + j_
                    if c_global < num_chunks and bool(
                        decode_common.chunk_relevant(
                            c_global * chunk, chunk, int(lens[b_]), window)
                    ):
                        live.append(c_idx)
                        logical.append(c_global)
                if logical and not (
                    start <= logical[0] and logical[-1] < end
                ):
                    raise DomainPurityError(
                        f"flash_decode_split: cell {(b_, h_, s_)} walked "
                        f"chunks {logical} outside its range {(start, end)}"
                    )
                cells.append(CellTrace(
                    cell=(b_, h_, s_), head=h_,
                    cell_domain=layout_lib.domain_of_head(
                        h_, num_kv_heads, num_domains),
                    touched=tuple(touched), live=tuple(live),
                    live_logical=tuple(logical),
                ))
    return AccessTrace(
        kernel="flash_decode_split", policy=layout_lib.HEAD_ALIGNED,
        num_kv_heads=num_kv_heads, num_domains=num_domains, cells=cells,
    )


def trace_plan(
    plan,
    page_table: np.ndarray,
    lengths: Sequence[int],
    *,
    num_kv_heads: int,
    num_domains: int = 2,
    window: Optional[int] = None,
) -> AccessTrace:
    """Trace whatever kernel an :class:`repro.kernels.plan.AttentionPlan`
    would launch for this page table (paged one-pass or split-K decode),
    using the plan's own ``page_size``/``num_splits``/``placement``."""
    policy = getattr(plan, "placement", None) or layout_lib.HEAD_ALIGNED
    return trace_paged_decode(
        page_table, lengths,
        num_kv_heads=num_kv_heads,
        page_size=plan.page_size,
        num_splits=max(1, int(plan.num_splits or 1)),
        window=window if window is not None else plan.window,
        policy=policy,
        num_domains=num_domains,
    )
