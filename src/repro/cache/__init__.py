"""Paged KV-cache subsystem: allocator, prefix sharing, NUMA placement.

Modules:
  pool    fixed-size page allocator (free list, refcounts, COW, page tables)
  prefix  hash-chain longest-shared-prefix page reuse across requests
  layout  head-aligned vs interleaved page placement + modeled traffic
  quant   int8/fp8 page codes + per-(head, page) dequant scales
  tier    host-memory page store behind the device pool (demote/promote)
"""

from repro.cache import layout, pool, prefix, quant, tier  # noqa: F401
from repro.cache.layout import (  # noqa: F401
    HEAD_ALIGNED,
    INTERLEAVED,
    PAGE_POLICIES,
    PagedTraffic,
    compare_policies,
    decode_page_traffic,
    domain_of_head,
    domain_of_page,
)
from repro.cache.pool import NULL_PAGE, OutOfPages, PagePool, SequencePages  # noqa: F401
from repro.cache.prefix import PrefixCache, page_hashes  # noqa: F401
from repro.cache.tier import HostPageStore  # noqa: F401
