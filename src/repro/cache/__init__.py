"""Paged KV-cache subsystem: allocator, prefix sharing, NUMA placement.

Modules:
  pool    fixed-size page allocator (free list, refcounts, COW, page tables)
  prefix  hash-chain longest-shared-prefix page reuse across requests
  layout  head-aligned vs interleaved page placement + modeled traffic
"""

from repro.cache import layout, pool, prefix  # noqa: F401
from repro.cache.layout import (  # noqa: F401
    HEAD_ALIGNED,
    INTERLEAVED,
    PAGE_POLICIES,
    PagedTraffic,
    compare_policies,
    decode_page_traffic,
    domain_of_head,
    domain_of_page,
)
from repro.cache.pool import NULL_PAGE, OutOfPages, PagePool, SequencePages  # noqa: F401
from repro.cache.prefix import PrefixCache, page_hashes  # noqa: F401
