"""Hash-based prefix sharing: longest-shared-prefix page reuse.

Requests in a serving mix overwhelmingly share their head — system prompts,
few-shot preambles, multi-turn history. Prefilling that head once and
letting every later request reference the same physical pages is the
serving-scale form of the paper's ACC reuse: the shared pages are the KV
working set that stays resident in a domain's cache while every sequence
attending it hits.

Granularity is one **full page**: a page's K/V content is determined by the
token ids of every position up to and including that page (K/V at position
i depends on tokens[0..i] only through the token at i and its RoPE position
— but the *hidden state* feeding the projections depends on the whole
prefix), so a page is reusable exactly when the entire token prefix up to
its end matches. That is captured by a hash chain:

    h_0   = H(tokens[0:ps])
    h_j   = H(h_{j-1}, tokens[j*ps:(j+1)*ps])

and the cache maps ``h_j -> physical page id``. Lookup walks the chain and
stops at the first miss — the longest shared prefix, by construction.

The cache owns one pool reference per cached page. Eviction is LRU over
chain entries and only frees pages no live sequence still references
(refcount 1 == only the cache holds it); entries whose page is still shared
are skipped, not freed. Evicting h_j while h_{j+1} survives merely strands
the longer entry until its own eviction — lookups stop at the hole.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import List, Sequence, Tuple

import numpy as np

from repro.cache.pool import PagePool


def page_hashes(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Chain hashes of every *full* page of ``tokens``."""
    toks = np.asarray(tokens).reshape(-1)
    out: List[bytes] = []
    prev = b""
    for j in range(len(toks) // page_size):
        h = hashlib.sha256()
        h.update(prev)
        h.update(np.ascontiguousarray(
            toks[j * page_size : (j + 1) * page_size], dtype=np.int64
        ).tobytes())
        prev = h.digest()
        out.append(prev)
    return out


class PrefixCache:
    """chain-hash -> physical page id, LRU-ordered, pool-ref-owning."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()
        self.hits = 0
        self.queries = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, hashes: Sequence[bytes], touch: bool = True) -> List[int]:
        """Physical pages of the longest cached prefix of ``hashes``.

        Does NOT take references — callers incref via
        ``pool.allocate_sequence(shared_prefix=...)`` while the entries are
        still cache-pinned. Matched entries are refreshed to MRU and the
        hit/query counters advance; ``touch=False`` is a pure peek (for
        admission *pricing*, which may probe the same request every
        scheduling round without distorting LRU order or the hit rate).
        """
        pages: List[int] = []
        for h in hashes:
            pid = self._entries.get(h)
            if pid is None:
                break
            if touch:
                self._entries.move_to_end(h)
            pages.append(pid)
        if touch:
            self.queries += len(hashes)
            self.hits += len(pages)
        return pages

    def insert(self, hashes: Sequence[bytes], pages: Sequence[int]) -> int:
        """Register ``pages`` (the physical backing of full pages whose chain
        hashes are ``hashes``), taking one pool reference per new entry.
        Returns the number of entries actually added."""
        if len(hashes) != len(pages):
            raise ValueError("hashes and pages must align")
        added = 0
        for h, pid in zip(hashes, pages):
            if h in self._entries:
                self._entries.move_to_end(h)
                continue
            self.pool.incref(pid)
            self._entries[h] = pid
            added += 1
        return added

    def evict(self, max_pages: int, on_evict=None) -> int:
        """Free up to ``max_pages`` pool pages by dropping LRU entries whose
        page only the cache still references. Returns pages freed.

        ``on_evict(hash, pid)``, when given, fires for each victim *before*
        its entry is dropped and its reference released — the page content
        is still valid at call time. This is the KV-tiering demotion hook:
        the serving backend copies the page host-side here, so eviction
        reclaims capacity without losing the content."""
        freed = 0
        if max_pages <= 0:
            return freed
        for h in list(self._entries):
            pid = self._entries[h]
            if self.pool.refcount(pid) > 1:
                # A live sequence still shares it: dropping the entry would
                # not free the page, only lose future sharing. Keep it.
                continue
            if on_evict is not None:
                on_evict(h, pid)
            del self._entries[h]
            self.evictions += 1
            freed += bool(self.pool.decref(pid))
            if freed >= max_pages:
                break
        return freed

    def pages(self) -> List[int]:
        """Physical pages currently pinned by cache entries (one list item
        per entry — a page cached under several chain hashes appears once
        per entry, matching the references held)."""
        return list(self._entries.values())

    def drain(self) -> int:
        """Teardown: drop every entry and its pool reference regardless of
        sharing (unlike :meth:`evict`, which skips live pages). Returns
        pages actually freed. After this the cache holds no references, so
        ``pool.check_leaks`` sees only the live sequences'."""
        freed = 0
        for h in list(self._entries):
            pid = self._entries.pop(h)
            freed += bool(self.pool.decref(pid))
        return freed

    @property
    def hit_rate(self) -> float:
        return self.hits / self.queries if self.queries else 0.0

    def stats(self) -> Tuple[int, int, int]:
        """(entries, hits, queries)."""
        return len(self._entries), self.hits, self.queries

    def counters(self) -> dict:
        """Full counter view (PR 7): everything :meth:`stats` reports plus
        evictions and the page-level lookup hit rate — the numbers the
        serving backends surface through ``prefix_stats()``."""
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "queries": self.queries,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }
