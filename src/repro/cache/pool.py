"""Fixed-size KV page allocator: free list, ref counts, copy-on-write.

The control plane of the paged KV cache (host-side, pure Python/numpy — the
actual K/V data lives in jnp arrays owned by the engine and indexed by the
page ids handed out here). Design mirrors vLLM's block manager, shrunk to
what the NUMA story needs:

  * a pool of ``num_pages`` physical pages of ``page_size`` tokens each,
    LIFO free list (hot pages are reused first — they are the ones most
    likely still resident in a domain's cache),
  * physical page 0 is the reserved **null page**: never allocated, it is
    the write/read sink for inactive decode rows so the jitted decode step
    can scatter unconditionally without corrupting live data,
  * per-page reference counts. A page with ``refcount > 1`` is shared
    (prefix cache and/or forked sequences) and therefore read-only; the
    pool's :meth:`ensure_writable` implements copy-on-write by allocating a
    fresh page and telling the caller which physical copy to perform,
  * per-sequence page tables (:class:`SequencePages`): the ordered list of
    physical pages backing one growing sequence, plus its token length.

The pool never touches array data; COW and page writes surface as
``(src_page, dst_page)`` copy instructions the engine applies to its jnp
page arrays. That split keeps the allocator exactly testable and the jitted
compute free of host round-trips beyond the page-table ints it already
needs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

NULL_PAGE = 0


class PoolError(RuntimeError):
    """Base class for page-pool misuse; every typed pool error derives
    from it so callers (and the sanitizer) can catch the family."""


class OutOfPages(PoolError):
    """Raised when an allocation cannot be satisfied; the serving engine
    reacts by evicting prefix-cache pages and/or preempting sequences."""


class SequenceReleasedError(PoolError):
    """An operation (release/append/fork) hit a sequence whose pages were
    already returned to the pool. Double releases used to be silent no-ops
    — which is exactly how refcount desyncs hide — so they are typed
    errors now."""


class RefcountLeakError(PoolError):
    """:meth:`PagePool.check_leaks` found pages whose refcounts do not
    match the live references the caller claims exist (engine teardown
    left sequences or prefix entries holding pages)."""

    def __init__(self, leaks: Dict[int, Tuple[int, int]]):
        self.leaks = leaks
        detail = ", ".join(
            f"page {pid}: rc={actual} expected={expected}"
            for pid, (actual, expected) in sorted(leaks.items())
        )
        super().__init__(f"refcount leaks: {detail}")


@dataclasses.dataclass
class SequencePages:
    """Page table of one sequence: physical pages, in logical order."""

    pages: List[int]
    length: int = 0  # tokens currently stored
    released: bool = False

    def num_pages(self) -> int:
        return len(self.pages)

    def tail_page(self) -> int:
        if not self.pages:
            raise ValueError("empty sequence has no tail page")
        return self.pages[-1]


class PagePool:
    """Allocator for ``num_pages`` physical pages of ``page_size`` tokens."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list; page 0 reserved as the null page.
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refcount = [0] * num_pages
        self._refcount[NULL_PAGE] = 1  # permanently pinned

    # -- raw page ops -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._refcount[pid]

    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages(
                f"no free pages ({self.num_pages - 1} total in pool)"
            )
        pid = self._free.pop()
        assert self._refcount[pid] == 0
        self._refcount[pid] = 1
        return pid

    def incref(self, pid: int) -> None:
        if pid == NULL_PAGE:
            return
        if self._refcount[pid] <= 0:
            raise ValueError(f"incref on free page {pid}")
        self._refcount[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        if pid == NULL_PAGE:
            return False
        rc = self._refcount[pid]
        if rc <= 0:
            raise ValueError(f"decref on free page {pid}")
        self._refcount[pid] = rc - 1
        if rc == 1:
            self._free.append(pid)
            return True
        return False

    # -- sequence ops -------------------------------------------------------

    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)

    def can_allocate(self, num_tokens: int, reserve: int = 0) -> bool:
        return self.free_pages >= self.pages_needed(num_tokens) + reserve

    def allocate_sequence(
        self, num_tokens: int, shared_prefix: Optional[List[int]] = None
    ) -> SequencePages:
        """Page table for a ``num_tokens``-token sequence.

        ``shared_prefix``: already-allocated pages (from the prefix cache)
        covering the first ``len(shared_prefix) * page_size`` tokens; the
        pool takes one reference on each. Remaining pages come off the free
        list; on exhaustion everything is rolled back and OutOfPages raised.
        """
        shared = list(shared_prefix or [])
        need = self.pages_needed(num_tokens)
        if len(shared) > need:
            raise ValueError("shared prefix longer than the sequence")
        fresh: List[int] = []
        try:
            for _ in range(need - len(shared)):
                fresh.append(self.alloc())
        except OutOfPages:
            for pid in fresh:
                self.decref(pid)
            raise
        for pid in shared:
            self.incref(pid)
        return SequencePages(pages=shared + fresh, length=num_tokens)

    def append_token(self, seq: SequencePages) -> Tuple[int, int, Optional[Tuple[int, int]]]:
        """Grow ``seq`` by one token; returns ``(page, offset, cow)``.

        ``page``/``offset`` locate the new token's slot. ``cow`` is None or a
        ``(src, dst)`` physical copy the engine must apply *before* writing —
        emitted when the token lands in a shared page (copy-on-write). A new
        page is allocated when the token starts a fresh page boundary.
        """
        if seq.released:
            raise SequenceReleasedError("append_token on a released sequence")
        pos = seq.length
        cow = None
        if pos % self.page_size == 0:
            seq.pages.append(self.alloc())
        else:
            tail = seq.tail_page()
            if self._refcount[tail] > 1:
                dst = self.alloc()
                self.decref(tail)
                seq.pages[-1] = dst
                cow = (tail, dst)
        seq.length = pos + 1
        return seq.tail_page(), pos % self.page_size, cow

    def reserve_tokens(
        self,
        seq: SequencePages,
        num_tokens: int,
        cows: Optional[List[Tuple[int, int]]] = None,
    ) -> List[Tuple[int, int]]:
        """Pre-grow ``seq`` by ``num_tokens`` slots in one go; returns the
        list of ``(src, dst)`` COW copies the engine must apply before any
        of the reserved slots is written.

        This is the host-side half of the fused multi-step decode scan: the
        scan writes up to N tokens per row without host intervention, so
        every page those tokens could land in must exist *before* launch.
        Built on :meth:`append_token` (one call per token) so page-boundary
        and copy-on-write behaviour — and the shadow sanitizer's view of
        both — is identical to N single-step appends. On ``OutOfPages``
        the partial progress is kept (``seq.length`` reflects it; COWs so
        far are in ``cows`` when the caller passed its own list), so the
        caller can free room and re-request the remainder.
        """
        out = cows if cows is not None else []
        for _ in range(num_tokens):
            _, _, cow = self.append_token(seq)
            if cow is not None:
                out.append(cow)
        return out

    def trim_tokens(self, seq: SequencePages, new_length: int) -> int:
        """Shrink ``seq`` back to ``new_length`` tokens, returning now-unused
        tail pages to the pool; returns #pages freed.

        The inverse of an over-reservation: a scan that stopped early (stop
        token, all rows done) consumed fewer slots than were reserved, and
        the untouched tail pages go straight back on the free list.
        """
        if seq.released:
            raise SequenceReleasedError("trim_tokens on a released sequence")
        if not 0 <= new_length <= seq.length:
            raise ValueError(
                f"trim_tokens to {new_length} outside [0, {seq.length}]"
            )
        keep = self.pages_needed(new_length)
        freed = 0
        while len(seq.pages) > keep:
            freed += bool(self.decref(seq.pages.pop()))
        seq.length = new_length
        return freed

    def fork(self, seq: SequencePages) -> SequencePages:
        """A new sequence sharing every page of ``seq`` (beam/parallel
        sampling). All pages — including the partial tail — are shared;
        the first divergent append triggers COW on the tail."""
        if seq.released:
            raise SequenceReleasedError("fork of a released sequence")
        for pid in seq.pages:
            self.incref(pid)
        return SequencePages(pages=list(seq.pages), length=seq.length)

    def release(self, seq: SequencePages) -> int:
        """Drop the sequence's references; returns #pages actually freed
        (shared pages survive under their remaining references).

        Releasing an already-released sequence raises
        :class:`SequenceReleasedError` — a silent no-op here is how a
        double-decref elsewhere stays hidden until pages alias."""
        if seq.released:
            raise SequenceReleasedError(
                "release of an already-released sequence"
            )
        freed = 0
        for pid in seq.pages:
            freed += bool(self.decref(pid))
        seq.pages = []
        seq.length = 0
        seq.released = True
        return freed

    # -- invariants ---------------------------------------------------------

    def check_leaks(
        self,
        live_refs: Optional[Dict[int, int]] = None,
        raise_on_leak: bool = True,
    ) -> Dict[int, Tuple[int, int]]:
        """Verify every page's refcount against the caller's claimed live
        references.

        ``live_refs`` maps page id -> number of references the caller still
        legitimately holds (live sequences' page tables, prefix-cache
        entries). Omitted pages are expected free. The null page's
        permanent pin is accounted for automatically. Returns
        ``{pid: (actual_rc, expected_rc)}`` for every mismatch; raises
        :class:`RefcountLeakError` on mismatch unless ``raise_on_leak`` is
        False. Also validates free-list consistency (a freed page must have
        rc == 0 and appear exactly once)."""
        expected = dict(live_refs or {})
        expected[NULL_PAGE] = expected.get(NULL_PAGE, 0) + 1
        leaks: Dict[int, Tuple[int, int]] = {}
        for pid in range(self.num_pages):
            want = expected.get(pid, 0)
            have = self._refcount[pid]
            if have != want:
                leaks[pid] = (have, want)
        free_set = set(self._free)
        if len(free_set) != len(self._free):  # duplicate free-list entry
            dupes = sorted(p for p in free_set if self._free.count(p) > 1)
            for pid in dupes:
                leaks[pid] = (self._refcount[pid], -self._free.count(pid))
        for pid in free_set:
            if self._refcount[pid] != 0:
                leaks.setdefault(pid, (self._refcount[pid], 0))
        if leaks and raise_on_leak:
            raise RefcountLeakError(leaks)
        return leaks
