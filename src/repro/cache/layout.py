"""NUMA-aware page placement: which domain's memory stripe holds a page.

The paper's WG->XCD mapping decides which *compute* domain runs each
(batch, kv-head) attention cell; at serving scale the dual question is
which *memory* domain holds the KV pages that cell reads. Two policies:

  * ``head_aligned`` — the physical page arrays are head-major
    ``(Hkv, num_pages, page_size, D)`` and the head axis is striped across
    domains exactly like the compute grid (contiguous head blocks, the same
    function ``core.placement`` uses for pod sharding). Every page a cell
    (b, h) reads lives in the domain that executes the cell: all fetches
    are domain-local, and pages shared between sequences (prefix sharing)
    are cached once per owning domain.
  * ``interleaved`` — the naive baseline: pages are handed out round-robin
    across domain stripes irrespective of head (physical layout
    ``(num_pages, Hkv, page_size, D)``, page -> domain = pid % domains).
    A cell's page walk scatters over every domain: ``(d-1)/d`` of the bytes
    cross the inter-domain fabric, and a shared page occupies *every*
    domain's cache instead of one.

``decode_page_traffic`` charges a mixed decode batch (real page tables from
the serving engine, or synthetic ones) under either policy, counting
local/remote bytes with once-per-(domain, page) reuse for pages shared
across sequences — the paged analogue of ``kernels.hbm_block_fetches``.
``core.perf_model.estimate_paged_decode`` is the O(1) analytic form and
``core.cache_sim.simulate_paged_decode`` the event-driven cross-check; both
consume the ``domain_of_head`` / ``domain_of_page`` functions defined here
so the three layers can never disagree on the placement arithmetic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from repro.core.numa import Topology

HEAD_ALIGNED = "head_aligned"
INTERLEAVED = "interleaved"

PAGE_POLICIES = (HEAD_ALIGNED, INTERLEAVED)


# -----------------------------------------------------------------------------
# Split-K decode: page-range partitioning (PR 4)
# -----------------------------------------------------------------------------


def decode_split_ranges(
    num_units: int, num_splits: int
) -> Tuple[Tuple[int, int], ...]:
    """Partition a decode cell's KV walk of ``num_units`` units (pages for
    the paged kernel, KV chunks for the dense one) into ``num_splits``
    contiguous half-open ranges ``(start, end)``.

    This is the single source of truth for split-K boundaries: the
    kernels derive their per-split grid extent from it, the tests prove
    domain alignment against it, and ``ref.split_decode_attention``
    replays it. Boundaries are **unit-granular by construction** — a
    split never bisects a page/chunk, which under the head-major pool
    (``HEAD_ALIGNED``: every page of a KV head lives in that head's
    domain stripe) means a split never straddles NUMA domains either
    (:func:`split_ranges_domain_aligned`). Ranges are equal-width
    (``ceil(num_units / num_splits)``) except the trailing one, which may
    be short when ``num_splits`` does not divide ``num_units``; ranges
    that would be *empty* are dropped, so the returned split count can be
    below ``num_splits`` (e.g. 5 units over 4 requested splits -> three
    ranges of 2+2+1) — a split grid cell always has real work.
    """
    if num_units <= 0:
        return ((0, 0),)
    s = max(1, min(int(num_splits), int(num_units)))
    per = -(-num_units // s)
    s = -(-num_units // per)  # drop empty trailing ranges
    return tuple(
        (i * per, min((i + 1) * per, num_units))
        for i in range(s)
    )


def split_ranges_domain_aligned(
    ranges: Sequence[Tuple[int, int]],
    *,
    head: int,
    policy: str,
    num_kv_heads: int,
    num_domains: int,
) -> bool:
    """True iff every page range reads from a single memory domain for
    ``head`` under ``policy`` — the property that makes split-K NUMA-clean:
    each split's partial pass stays inside one domain's cache. Holds for
    every range under ``HEAD_ALIGNED`` (a head's pages share a domain by
    construction); fails for any multi-page range under ``INTERLEAVED``
    when ``num_domains > 1`` — which is exactly why the pool is
    head-major."""
    for start, end in ranges:
        domains = {
            domain_of_page(pid, head, policy, num_kv_heads, num_domains)
            for pid in range(start, end)
        }
        if len(domains) > 1:
            return False
    return True


def domain_of_head(head: int, num_kv_heads: int, num_domains: int) -> int:
    """Compute/memory domain owning a KV head: contiguous head blocks (the
    head-first grid's PARALLEL split, and ``core.placement``'s shard map)."""
    if num_kv_heads >= num_domains:
        return head * num_domains // num_kv_heads
    return head % num_domains


def device_of_head(head: int, num_kv_heads: int, num_devices: int) -> int:
    """Mesh device owning a KV head under the head-sharded serving pool.

    The recursive form of :func:`domain_of_head`: ``NamedSharding`` on the
    pool's leading head axis hands out contiguous head blocks per device,
    so this is the same arithmetic one tier up. The sharded backends, the
    per-device page budgets, and ``core.perf_model``'s inter-device tier
    all consume this one function so the three layers can never disagree
    on which device's HBM a head's pages occupy."""
    if num_devices <= 1:
        return 0
    if num_kv_heads >= num_devices:
        return head * num_devices // num_kv_heads
    return head % num_devices


def domain_of_page(
    pid: int, head: int, policy: str, num_kv_heads: int, num_domains: int
) -> int:
    """Memory domain holding physical page ``pid`` of head ``head``."""
    if policy == HEAD_ALIGNED:
        return domain_of_head(head, num_kv_heads, num_domains)
    if policy == INTERLEAVED:
        return pid % num_domains
    raise ValueError(f"unknown page placement policy {policy!r}")


@dataclasses.dataclass(frozen=True)
class PagedTraffic:
    """Modeled bytes for one decode tick over paged KV."""

    policy: str
    total_bytes: int     # all K/V bytes the grid cells request
    local_bytes: int     # served from the cell's own domain stripe
    remote_bytes: int    # crossed the inter-domain fabric
    unique_bytes: int    # after once-per-(domain, head, page) coalescing
    reuse_hits: int      # page fetches saved by sharing within the tick
    page_fetches: int    # unique (domain, head, page) fills

    @property
    def local_fraction(self) -> float:
        return self.local_bytes / self.total_bytes if self.total_bytes else 1.0

    @property
    def reuse_rate(self) -> float:
        total = self.reuse_hits + self.page_fetches
        return self.reuse_hits / total if total else 0.0

    def time(self, topo: Topology) -> float:
        """Memory-side seconds for the tick: local bytes ride HBM, remote
        bytes additionally squeeze through the per-domain fabric link."""
        t_hbm = self.unique_bytes / topo.hbm_bw
        remote_unique = self.unique_bytes * (
            self.remote_bytes / self.total_bytes if self.total_bytes else 0.0
        )
        t_link = remote_unique / max(topo.link_bw * topo.num_domains, 1.0)
        return t_hbm + t_link


def decode_page_traffic(
    page_tables: Sequence[Sequence[int]],
    lengths: Sequence[int],
    *,
    num_kv_heads: int,
    page_size: int,
    head_dim: int,
    topo: Topology,
    policy: str = HEAD_ALIGNED,
    dtype_bytes: int = 2,
) -> PagedTraffic:
    """Charge one decode tick: every (sequence, kv head) cell walks its live
    pages. A (domain, head, page) triple is fetched from memory once per
    tick (later readers hit the domain cache) — that is where prefix-shared
    pages pay off, and only ``head_aligned`` keeps them in a single domain.
    """
    page_bytes = 2 * page_size * head_dim * dtype_bytes  # K and V
    seen = set()
    total = local = unique = 0
    reuse_hits = 0
    for pages, length in zip(page_tables, lengths):
        live = -(-int(length) // page_size)
        for h in range(num_kv_heads):
            cell_dom = domain_of_head(h, num_kv_heads, topo.num_domains)
            for pid in list(pages)[:live]:
                page_dom = domain_of_page(
                    int(pid), h, policy, num_kv_heads, topo.num_domains
                )
                total += page_bytes
                if page_dom == cell_dom:
                    local += page_bytes
                key = (cell_dom, h, int(pid))
                if key in seen:
                    reuse_hits += 1
                else:
                    seen.add(key)
                    unique += page_bytes
    return PagedTraffic(
        policy=policy,
        total_bytes=total,
        local_bytes=local,
        remote_bytes=total - local,
        unique_bytes=unique,
        reuse_hits=reuse_hits,
        page_fetches=len(seen),
    )


def compare_policies(
    page_tables: Sequence[Sequence[int]],
    lengths: Sequence[int],
    *,
    num_kv_heads: int,
    page_size: int,
    head_dim: int,
    topo: Topology,
    dtype_bytes: int = 2,
) -> Dict[str, PagedTraffic]:
    """Both placement policies over the same tick (benchmark A/B)."""
    return {
        policy: decode_page_traffic(
            page_tables, lengths,
            num_kv_heads=num_kv_heads, page_size=page_size,
            head_dim=head_dim, topo=topo, policy=policy,
            dtype_bytes=dtype_bytes,
        )
        for policy in PAGE_POLICIES
    }
