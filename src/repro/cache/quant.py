"""Quantized KV pages: int8 / fp8 codes + per-(head, page) scales.

The million-token serving problem is first a *capacity* problem: at fp32 a
128K-token context holds ~2 GB of KV per layer-group, and the NUMA-aware
head-first placement only pays off if the pages fit on-device at all. This
module shrinks the paged pool 4x (int8/fp8 codes, one fp32 scale per
(kv head, physical page) for K and V each) while keeping the *dequantize
point inside the Pallas kernel bodies*: pools stream as 1-byte codes and
widen to fp32 in VMEM right before the QK^T/PV matmuls, so HBM traffic —
the thing decode is bound on — shrinks with the storage.

Scale metadata is **page-table metadata**: a ``(Hkv, num_pages)`` fp32
array per pool, indexed by *physical* page id exactly like the pool
itself, riding the same scalar-prefetch SMEM path the page table uses
(``kernels/paged_decode_attention.py`` / ``paged_prefill_attention.py``).
Nothing outside ``src/repro/kernels/`` and this module may do arithmetic
on the scales (lint rule ``kv-scales-ride-page-table``): serving and model
code thread them opaquely, keyed by the page table.

Write paths quantize **per page with rescale-on-append**: a page's scale
is the running amax of everything written into it; when a new token's row
exceeds the current scale's range, the page's existing codes are rescaled
(``codes * old_scale / new_scale`` — a shrink, never an overflow) in the
same jitted update. Copy-on-write copies codes verbatim and duplicates the
scale entry (``cow_scales``), so a forked page dequantizes identically.

Symmetric schemes, zero-point-free:

  * ``int8`` — codes in [-127, 127], ``scale = amax / 127``;
  * ``fp8``  — ``float8_e4m3fn`` codes, ``scale = amax / 448`` (the e4m3
    max normal), which keeps the format's relative precision centred on
    the page's live range;
  * ``fp32`` — identity (no scales allocated anywhere).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "KV_DTYPES",
    "QMAX",
    "append_rows",
    "cow_scales",
    "dequantize_pages",
    "kv_dtype_of",
    "kv_itemsize",
    "quantize_pages",
    "scale_nbytes",
    "scatter_pages",
    "storage_dtype",
    "validate_kv_dtype",
]

#: Supported pool storage formats, in the order the docs list them.
KV_DTYPES = ("fp32", "int8", "fp8")

#: Largest representable magnitude per quantized format (int8 symmetric
#: range; float8_e4m3fn max normal).
QMAX = {"int8": 127.0, "fp8": 448.0}


def validate_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(
            f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}"
        )
    return kv_dtype


def storage_dtype(kv_dtype: str):
    """The jnp dtype the pool arrays are stored as."""
    validate_kv_dtype(kv_dtype)
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return jnp.float8_e4m3fn
    return jnp.float32


def kv_itemsize(kv_dtype: str) -> int:
    """Bytes per pool element — what ``_page_slice_bytes`` accounting and
    the perf model's ``dtype_bytes`` consume."""
    return 1 if kv_dtype in QMAX else 4


def kv_dtype_of(dtype) -> str:
    """The ``kv_dtype`` name a pool array's jnp dtype corresponds to — how
    the model layer recognises a quantized pool it was handed (any wider
    dtype, fp32/bf16, reads as the unquantized "fp32" scheme)."""
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.int8):
        return "int8"
    if d == jnp.dtype(jnp.float8_e4m3fn):
        return "fp8"
    return "fp32"


def scale_nbytes(num_kv_heads: int, num_pages: int, kv_dtype: str) -> int:
    """Bytes of scale metadata per pool array (0 for fp32): one fp32 per
    (kv head, physical page)."""
    if kv_dtype not in QMAX:
        return 0
    return 4 * num_kv_heads * num_pages


def _safe(s):
    return jnp.where(s > 0.0, s, 1.0)


def _encode(x, kv_dtype: str):
    """fp32 -> codes at unit scale (caller has already divided)."""
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(x), -127.0, 127.0).astype(jnp.int8)
    return x.astype(storage_dtype(kv_dtype))


def quantize_pages(pages, kv_dtype: str):
    """Quantize a full pool ``(Hkv, P, page_size, hd)`` (or any array whose
    last two axes are the page content) to ``(codes, scales)`` with one
    scale per leading index pair — ``(Hkv, P)`` for a pool.

    fp32 returns ``(pages, None)`` so callers can thread unconditionally.
    """
    validate_kv_dtype(kv_dtype)
    if kv_dtype == "fp32":
        return jnp.asarray(pages, jnp.float32), None
    x = jnp.asarray(pages, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=(-2, -1))
    scales = amax / QMAX[kv_dtype]
    codes = _encode(x / _safe(scales)[..., None, None], kv_dtype)
    return codes, scales.astype(jnp.float32)


def dequantize_pages(codes, scales):
    """Inverse of :func:`quantize_pages`: ``codes (..., ps, hd)`` x
    ``scales (...)`` -> fp32. ``scales=None`` is the fp32 identity."""
    x = jnp.asarray(codes, jnp.float32)
    if scales is None:
        return x
    return x * jnp.asarray(scales, jnp.float32)[..., None, None]


def append_rows(pages, scales, rows, pids, offs, kv_dtype: str):
    """Scatter one new token row per sequence into quantized pages,
    rescaling each touched page when the new row widens its range.

    ``pages``: ``(Hkv, P, ps, hd)`` codes; ``scales``: ``(Hkv, P)`` fp32;
    ``rows``: ``(Hkv, B, hd)`` fp32 new K (or V) rows; ``pids``/``offs``:
    ``(B,)`` int32 physical page / in-page offset per sequence (distinct
    pages across the batch by construction — every live row owns its tail
    page exclusively, COW guarantees it). Returns ``(pages, scales)``
    updated functionally (jit/donation-friendly).

    The rescale is the one place quantized pages lose information beyond
    the format itself: existing codes shrink by ``old_scale / new_scale``
    (<= 1) when a louder token arrives. fp32 degenerates to the plain
    scatter with ``scales`` passed through untouched (``None``).
    """
    validate_kv_dtype(kv_dtype)
    rows = jnp.asarray(rows, jnp.float32)
    if kv_dtype == "fp32":
        return pages.at[:, pids, offs].set(rows.astype(pages.dtype)), scales
    qmax = QMAX[kv_dtype]
    old_s = scales[:, pids]                       # (Hkv, B)
    row_amax = jnp.max(jnp.abs(rows), axis=-1)    # (Hkv, B)
    new_s = jnp.maximum(old_s, row_amax / qmax)
    # Rescale the touched pages' existing codes to the widened scale.
    touched = jnp.asarray(pages[:, pids], jnp.float32)   # (Hkv, B, ps, hd)
    ratio = (old_s / _safe(new_s))[..., None, None]
    rescaled = _encode(touched * ratio, kv_dtype)
    new_codes = _encode(rows / _safe(new_s)[..., None], kv_dtype)
    rescaled = rescaled.at[:, jnp.arange(pids.shape[0]), offs].set(new_codes)
    pages = pages.at[:, pids].set(rescaled)
    scales = scales.at[:, pids].set(new_s)
    return pages, scales


def scatter_pages(pages, scales, new, pids, kv_dtype: str):
    """Write whole freshly-computed pages into the pool (prefill tail
    scatter): ``new`` is ``(..., n, ps, hd)`` fp32 page-shaped content,
    ``pids`` the ``(n,)`` destination physical ids along the pool's pages
    axis (third from the end). Quantized pools store codes and set the
    destinations' scale entries; fp32 degenerates to the plain set with
    ``scales`` passed through (``None``). Destinations are freshly
    allocated (or the write-sink null page), so per-page amax
    quantization is exact — nothing pre-existing to rescale."""
    validate_kv_dtype(kv_dtype)
    if kv_dtype == "fp32":
        return pages.at[..., pids, :, :].set(new.astype(pages.dtype)), scales
    codes, s = quantize_pages(new, kv_dtype)
    pages = pages.at[..., pids, :, :].set(codes.astype(pages.dtype))
    scales = scales.at[..., pids].set(s.astype(scales.dtype))
    return pages, scales


def cow_scales(scales, src, dst):
    """Copy-on-write metadata step: the scale entry follows the page copy
    (``dst`` dequantizes identically to ``src``). fp32 passthrough. The
    pages axis is last in the scale layout, so this serves both the flat
    ``(Hkv, P)`` arrays and the scanned stacks' ``(periods, Hkv, P)``."""
    if scales is None:
        return scales
    return scales.at[..., dst].set(scales[..., src])
