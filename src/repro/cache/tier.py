"""Hierarchical KV tiering: a host-memory page store behind the device pool.

The million-token serving problem outgrows device HBM even at int8: the
paged pool is a fixed budget, and before this module the only pressure
valves were prefix-cache *eviction* (recompute the prefix next time) and
sequence *preemption* (recompute everything). Both throw away work that a
host-DRAM copy would have kept: device↔host link bandwidth is far below
HBM, but a page transfer is orders of magnitude cheaper than re-prefilling
the tokens behind it (``core.perf_model.HOST_LINK_BW`` prices it).

:class:`HostPageStore` is that second tier — an LRU, byte-budgeted store of
**demoted** pages, keyed by the same prefix-chain hashes the device
:class:`~repro.cache.prefix.PrefixCache` uses:

  * **demote** — under pool pressure the serving backend copies a cold
    page's K/V payload (every layer, plus quantized scales) host-side and
    *then* frees the device page: capacity is reclaimed without losing the
    content. Cold = prefix-cache tail entries and preempted sequences'
    prefixes.
  * **promote-on-admit** — admission continues a request's chain-hash walk
    into the host store where the device cache's match ends; matched
    payloads are restored into freshly allocated device pages and
    re-registered with the device prefix cache, so the request extends off
    them exactly as if they had never left.

The store is deliberately dumb about *what* a payload is: the backend hands
it an opaque per-layer tree of host (numpy) arrays and gets the same object
back at promotion. Keys are chain hashes, so a payload is valid for any
request whose token prefix matches — the same sharing contract the device
prefix cache implements, one tier down.

The allocator remains :class:`~repro.cache.pool.PagePool`; this store never
holds device page ids (a demoted page's id is freed and may be reused
immediately). Residency is therefore exclusive by construction: a hash is
either device-resident (prefix cache), host-resident (here), or gone —
``analysis.pool_sanitizer.ShadowTier`` enforces exactly that.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["HostPageStore"]


class HostPageStore:
    """LRU host-memory store of demoted KV pages, keyed by chain hash.

    ``capacity_bytes`` is the host-DRAM budget; ``page_nbytes`` the host
    footprint of one logical page's payload (all layers, K+V, codes +
    scales — the backend computes it once from its cache shapes). Admits
    beyond capacity evict LRU entries; a store too small for one page
    admits nothing (capacity 0 disables tiering cleanly).
    """

    def __init__(self, capacity_bytes: int, page_nbytes: int):
        if page_nbytes <= 0:
            raise ValueError("page_nbytes must be positive")
        self.page_nbytes = int(page_nbytes)
        self.capacity_pages = max(int(capacity_bytes) // self.page_nbytes, 0)
        self._lru: "OrderedDict[bytes, Any]" = OrderedDict()
        self.demotions = 0
        self.promotions = 0
        self.evictions = 0
        self.hits = 0
        self.queries = 0

    # -- capacity -----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, h: bytes) -> bool:
        return h in self._lru

    @property
    def bytes_resident(self) -> int:
        return len(self._lru) * self.page_nbytes

    @property
    def free_slots(self) -> int:
        return self.capacity_pages - len(self._lru)

    # -- demote -------------------------------------------------------------

    def admit(self, h: bytes, payload: Any) -> bool:
        """Store one demoted page's payload under its chain hash.

        Returns True when the page is host-resident afterwards. A re-admit
        of a resident hash refreshes it to MRU without copying (the
        payload under a chain hash is content-determined — two demotions
        of the same hash carry identical K/V). Overflow evicts LRU
        entries; a zero-capacity store rejects everything.
        """
        if self.capacity_pages <= 0:
            return False
        if h in self._lru:
            self._lru.move_to_end(h)
            return True
        while len(self._lru) >= self.capacity_pages:
            self._lru.popitem(last=False)
            self.evictions += 1
        self._lru[h] = payload
        self.demotions += 1
        return True

    # -- promote ------------------------------------------------------------

    def lookup_chain(self, hashes: Sequence[bytes]) -> List[bytes]:
        """The longest stored run of ``hashes`` (from the front), MRU-
        refreshing each hit — the host-tier continuation of
        ``PrefixCache.lookup``. Payloads stay put; :meth:`take` removes
        them once device pages are allocated to receive them."""
        out: List[bytes] = []
        for h in hashes:
            if h not in self._lru:
                break
            self._lru.move_to_end(h)
            out.append(h)
        self.queries += len(hashes)
        self.hits += len(out)
        return out

    def take(self, h: bytes) -> Any:
        """Remove and return a resident payload (promotion consumes the
        host copy — the page is device-resident again, and residency is
        exclusive). KeyError on a non-resident hash."""
        payload = self._lru.pop(h)  # KeyError = promote of absent page
        self.promotions += 1
        return payload

    def peek(self, h: bytes) -> Optional[Any]:
        """Payload under ``h`` without removing or touching it."""
        return self._lru.get(h)

    def discard(self, h: bytes) -> bool:
        """Drop a resident payload without counting a promotion: the hash
        became device-resident through a fresh prefill (not a restore), so
        the host copy is superseded — exclusive residency demands it go.
        Returns whether anything was dropped."""
        return self._lru.pop(h, None) is not None

    def drain(self) -> int:
        """Teardown: drop every payload; returns entries dropped."""
        n = len(self._lru)
        self._lru.clear()
        return n

    # -- introspection ------------------------------------------------------

    def counters(self) -> Dict[str, float]:
        return {
            "entries": float(len(self._lru)),
            "capacity_pages": float(self.capacity_pages),
            "bytes_resident": float(self.bytes_resident),
            "demotions": float(self.demotions),
            "promotions": float(self.promotions),
            "evictions": float(self.evictions),
            "hits": float(self.hits),
            "queries": float(self.queries),
        }
