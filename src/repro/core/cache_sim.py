"""Event-driven NUMA cache simulator for attention workgroup schedules.

TPUs expose no L2-hit-rate counter (and this container has no MI300X), so the
paper's evaluation — *throughput and cache hit rates per mapping strategy* —
is reproduced with a tile-granularity simulator:

  * ``num_domains`` domains, each with a private LRU cache of
    ``cache_bytes`` (4 MB L2 per XCD on MI300X) and ``slots_per_domain``
    concurrent workgroup slots (38 CUs per XCD),
  * hardware dispatch: workgroup ``wid`` is queued on domain
    ``wid % num_domains`` (chunked round-robin, chunk 1 — paper §2.2),
  * each workgroup's memory behaviour is its FA2 tile-access stream
    (Q row-block once, then the K/V tile sequence; the backward variant
    reads K/V once and streams Q/dO),
  * **MSHR miss coalescing**: an access to a line with an in-flight fill
    waits for that fill and counts as a hit. This is the convoy-forming
    mechanism on real hardware — misses act as barriers that keep
    workgroups sharing a stream position-synchronized,
  * read-once operands (Q in fwd, K/V tile in bwd) are non-temporal: they
    are fetched but do not displace the shared reuse window.

Timing is split into two clocks. A *dynamics* clock (with miss-latency
stalls) schedules the interleaving of concurrent workgroups — it produces the
drift/convoy behaviour that the hit rates depend on. The *throughput* model
is a per-domain roofline: ``elapsed = max(accesses * t_tile / efficiency,
hbm_bytes / (hbm_bw / num_domains))``, so a mapping that misses everywhere
becomes bandwidth-bound exactly as the paper observes (its FA2 tile has
~128 flop/B arithmetic intensity against MI300X's ~247 flop/B balance point).

Cost control: all four mappings are domain-symmetric, so we simulate **one
domain** and truncate its queue to ``max_wgs`` workgroups (the steady state
repeats per ACC). Cache capacity, tile sizes, sequence length and concurrency
are all kept at full fidelity — scaling any of them distorts the
working-set:window ratios that decide hit rates.

Calibration (documented in EXPERIMENTS.md): ``miss_latency=4`` tile-times,
``kernel_efficiency=0.72`` of peak for the hit path (Triton FA2 on MI300X
reaches ~65-75 % of peak). With these, the simulator reproduces the paper's
Fig. 12/13 numbers: 90-97 % hit for Swizzled Head-first at H=128/N=128K,
~40-60 % for Naive Head-first, ~0-1 % for block-first mappings, and the
corresponding up-to-50 % throughput gap.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import swizzle
from repro.core.numa import Topology
from repro.core.swizzle import AttentionGrid


@dataclasses.dataclass(frozen=True)
class AttentionWorkload:
    """One FA2 kernel launch to simulate."""

    grid: AttentionGrid
    seq_len: int
    head_dim: int
    block_m: int = 128
    block_n: int = 64
    causal: bool = True
    dtype_bytes: int = 2
    pass_: str = "fwd"  # "fwd" | "bwd"

    @property
    def kv_tiles_total(self) -> int:
        return -(-self.seq_len // self.block_n)

    def kv_tiles_for_block(self, m: int) -> int:
        """# of K/V tiles workgroup (.., m) reads (causal => prefix only)."""
        if not self.causal:
            return self.kv_tiles_total
        rows_end = min((m + 1) * self.block_m, self.seq_len)
        return -(-rows_end // self.block_n)

    @property
    def blocks_per_head(self) -> int:
        block = self.block_n if self.pass_ == "bwd" else self.block_m
        return -(-self.seq_len // block)

    @property
    def kv_tile_bytes(self) -> int:
        return self.block_n * self.head_dim * self.dtype_bytes

    @property
    def q_tile_bytes(self) -> int:
        return self.block_m * self.head_dim * self.dtype_bytes

    @property
    def flops_per_tile_pair(self) -> float:
        # QK^T + PV: two (block_m x block_n x head_dim) matmuls.
        return 4.0 * self.block_m * self.block_n * self.head_dim


@dataclasses.dataclass
class SimResult:
    mapping: str
    hits: int
    misses: int
    hbm_bytes: int          # one simulated domain, truncated queue
    elapsed: float          # seconds, one domain (roofline of compute vs HBM)
    compute_time: float
    hbm_time: float
    total_flops: float      # flops corresponding to the simulated accesses
    per_tensor: Dict[str, Tuple[int, int]]  # tensor -> (hits, misses)
    simulated_wgs: int
    total_wgs: int

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def throughput(self) -> float:
        """Model FLOP/s per domain (meaningful as a ratio between mappings)."""
        return self.total_flops / self.elapsed if self.elapsed else 0.0

    @property
    def bound(self) -> str:
        return "memory" if self.hbm_time > self.compute_time else "compute"


def _stream_len(wl: AttentionWorkload, m: int) -> int:
    """Number of tile accesses in workgroup (b, h, m)'s stream."""
    if wl.pass_ == "fwd":
        return 1 + 2 * wl.kv_tiles_for_block(m)
    q_blocks = -(-wl.seq_len // wl.block_m)
    if wl.causal:
        first_q = (m * wl.block_n) // wl.block_m
        return 2 + 2 * (q_blocks - first_q)
    return 2 + 2 * q_blocks


def _access(wl: AttentionWorkload, b: int, h: int, hkv: int, m: int, pos: int):
    """pos-th access of the workgroup -> (tile_key, nbytes, shared).

    Keys are (tensor_tag, batch, head, tile_idx); shared tensors key on the
    *kv* head — that is what makes ACC sharing visible to the cache.
    ``shared=False`` operands are read once per workgroup and pinned in
    LDS/registers, streaming through L2 with a non-temporal policy.
    """
    if wl.pass_ == "fwd":
        if pos == 0:
            return ("Q", b, h, m), wl.q_tile_bytes, False
        j = (pos - 1) >> 1
        tag = "K" if (pos - 1) & 1 == 0 else "V"
        return (tag, b, hkv, j), wl.kv_tile_bytes, True
    if pos == 0:
        return ("K", b, hkv, m), wl.kv_tile_bytes, False
    if pos == 1:
        return ("V", b, hkv, m), wl.kv_tile_bytes, False
    first_q = (m * wl.block_n) // wl.block_m if wl.causal else 0
    j = first_q + ((pos - 2) >> 1)
    tag = "Q" if (pos - 2) & 1 == 0 else "dO"
    return (tag, b, h, j), wl.q_tile_bytes, True


class _LRU:
    __slots__ = ("cap", "used", "d")

    def __init__(self, cap: int):
        self.cap = cap
        self.used = 0
        self.d: OrderedDict = OrderedDict()

    def touch(self, key) -> bool:
        if key in self.d:
            self.d.move_to_end(key)
            return True
        return False

    def insert(self, key, nbytes: int) -> None:
        d = self.d
        if key in d:
            d.move_to_end(key)
            return
        d[key] = nbytes
        self.used += nbytes
        while self.used > self.cap and d:
            _, sz = d.popitem(last=False)
            self.used -= sz


def simulate(
    mapping: str,
    workload: AttentionWorkload,
    topo: Topology,
    *,
    max_wgs: Optional[int] = None,
    miss_latency: float = 4.0,
    kernel_efficiency: float = 0.72,
    miss_overhead: float = 0.25,
    chunk: int = 8,
    domain: int = 0,
) -> SimResult:
    """Simulate one domain of one launch under one mapping strategy.

    ``miss_overhead``: fraction of a tile-time of *exposed* (non-hidden)
    latency each miss adds to the compute-side clock — on real hardware
    occupancy hides most but not all fill latency. Calibrated so the
    Naive Head-first mapping lands at the paper's ~0.90x relative
    performance at N_CTX=128K while hit-rate-parity mappings stay at 1.0x.
    """
    wl = workload
    grid = dataclasses.replace(wl.grid, blocks_per_head=wl.blocks_per_head)
    d = topo.num_domains
    nslots = topo.slots_per_domain

    # Dispatch queue for the simulated domain, truncated for tractability.
    wids = np.arange(grid.total_wgs, dtype=np.int64)
    sel = wids[swizzle.domain_of(wids, d) == domain]
    total_wgs_domain = len(sel)
    if max_wgs is not None and len(sel) > max_wgs:
        sel = sel[:max_wgs]
    qb, qh, qm = swizzle.decode(mapping, sel, grid, d)
    qhkv = qh // grid.group_size
    qb = qb.astype(np.int64)
    nq = len(sel)

    t_tile = wl.flops_per_tile_pair / 2.0 / (topo.flops_per_slot * kernel_efficiency)
    lam = miss_latency  # in t_tile units on the dynamics clock

    lru = _LRU(topo.cache_bytes)
    inflight: Dict[tuple, float] = {}
    hits = misses = 0
    hbm_bytes = 0
    accesses = 0
    per_tensor: Dict[str, list] = {t: [0, 0] for t in ("Q", "K", "V", "dO")}

    heap = []
    qi = 0
    for s in range(nslots):
        if qi < nq:
            heapq.heappush(heap, (0.0, s, qi, 0))
            qi += 1
    while heap:
        t, s, wi, pos = heapq.heappop(heap)
        b = int(qb[wi]); h = int(qh[wi]); hkv = int(qhkv[wi]); m = int(qm[wi])
        slen = _stream_len(wl, m)
        stop = min(pos + chunk, slen)
        while pos < stop:
            key, nbytes, shared = _access(wl, b, h, hkv, m, pos)
            accesses += 1
            if shared and lru.touch(key):
                hits += 1
                per_tensor[key[0]][0] += 1
                t += 1.0
            else:
                f = inflight.get(key)
                if f is not None and f > t:
                    # Hit-under-miss: wait for the in-flight fill.
                    hits += 1
                    per_tensor[key[0]][0] += 1
                    t = f + 1.0
                else:
                    misses += 1
                    per_tensor[key[0]][1] += 1
                    hbm_bytes += nbytes
                    tf = t + lam
                    inflight[key] = tf
                    if shared:
                        lru.insert(key, nbytes)
                    t = tf + 1.0
            pos += 1
        if pos < slen:
            heapq.heappush(heap, (t, s, wi, pos))
        elif qi < nq:
            heapq.heappush(heap, (t, s, qi, 0))
            qi += 1
    # Periodically drop stale in-flight entries is unnecessary: dict stays
    # bounded by distinct tiles touched.

    # Roofline throughput for the simulated domain. KV-pair flops accrue per
    # K/V access pair => flops = (K+V accesses)/2 * pair_flops.
    kv_accesses = sum(per_tensor[k][0] + per_tensor[k][1] for k in ("K", "V"))
    if wl.pass_ == "bwd":
        # bwd does ~2.5x the matmul work of fwd per tile pair (5 matmuls).
        pair_accesses = sum(per_tensor[k][0] + per_tensor[k][1] for k in ("Q", "dO"))
        flops = pair_accesses / 2.0 * wl.flops_per_tile_pair * 2.5
    else:
        flops = kv_accesses / 2.0 * wl.flops_per_tile_pair
    compute_time = flops / (topo.peak_flops / d * kernel_efficiency)
    # Exposed fill latency: misses stall their slot for a calibrated fraction
    # of a tile-time beyond what occupancy hides; the domain runs `nslots`
    # slots in parallel, so the domain-level penalty is averaged over them.
    compute_time += misses * t_tile * miss_overhead / max(nslots, 1)
    hbm_time = hbm_bytes / (topo.hbm_bw / d)
    return SimResult(
        mapping=mapping,
        hits=hits,
        misses=misses,
        hbm_bytes=hbm_bytes,
        elapsed=max(compute_time, hbm_time),
        compute_time=compute_time,
        hbm_time=hbm_time,
        total_flops=flops,
        per_tensor={k: tuple(v) for k, v in per_tensor.items()},
        simulated_wgs=nq,
        total_wgs=total_wgs_domain,
    )


def default_max_wgs(workload: AttentionWorkload, budget_accesses: int = 3_000_000) -> int:
    """Truncate the per-domain queue so simulated accesses stay tractable.

    Keeps at least two full ACC passes so steady state (incl. the head
    transition) is represented.
    """
    mean = (
        1 + (workload.blocks_per_head + 1) * workload.block_m / workload.block_n
        if workload.causal
        else 1 + 2 * workload.kv_tiles_total
    )
    min_wgs = 2 * workload.grid.group_size * workload.blocks_per_head
    return max(int(budget_accesses / max(mean, 1)), min(min_wgs, 4096))


# -----------------------------------------------------------------------------
# Paged decode: page-granular LRU replay of a serving tick
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class PagedSimResult:
    """One simulated decode tick over paged KV under a placement policy."""

    policy: str
    hits: int            # page reads served by a domain's cache
    misses: int          # page fills from memory
    hbm_bytes: int
    local_bytes: int     # fills served from the reading domain's own stripe
    remote_bytes: int    # fills crossing the inter-domain fabric
    elapsed: float       # seconds (memory-side roofline w/ link term)

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def local_fraction(self) -> float:
        tot = self.local_bytes + self.remote_bytes
        return self.local_bytes / tot if tot else 1.0


def simulate_paged_decode(
    page_tables,
    lengths,
    *,
    num_kv_heads: int,
    page_size: int,
    head_dim: int,
    topo: Topology,
    policy: str = "head_aligned",
    dtype_bytes: int = 2,
    group_size: int = 1,
) -> PagedSimResult:
    """Replay one decode tick: every (sequence, kv head) cell streams its
    live pages through its domain's LRU. The event-level cross-check of
    ``perf_model.estimate_paged_decode`` — it sees what the analytic form
    assumes away: capacity evictions when the shared working set outgrows
    a domain's cache, and the cache-footprint asymmetry of the two
    placement policies (an interleaved shared page lands in every reader
    domain's cache; a head-aligned one in exactly one).
    """
    from repro.cache import layout as layout_lib

    d = max(topo.num_domains, 1)
    page_bytes = 2 * page_size * head_dim * dtype_bytes
    lrus = [_LRU(topo.cache_bytes) for _ in range(d)]
    hits = misses = 0
    local_bytes = remote_bytes = 0
    flops = 0.0
    # Head-first dispatch: cell (b, h) runs in head h's domain. Walk cells
    # batch-innermost (all sequences of one head back to back) — the order
    # the PARALLEL (b, h) grid dims produce within one domain.
    for h in range(num_kv_heads):
        cell_dom = layout_lib.domain_of_head(h, num_kv_heads, d)
        lru = lrus[cell_dom]
        for pages, length in zip(page_tables, lengths):
            live = -(-int(length) // page_size)
            flops += 4.0 * group_size * int(length) * head_dim
            for pid in list(pages)[:live]:
                key = (h, int(pid))
                if lru.touch(key):
                    hits += 1
                    continue
                misses += 1
                lru.insert(key, page_bytes)
                page_dom = layout_lib.domain_of_page(
                    int(pid), h, policy, num_kv_heads, d
                )
                if page_dom == cell_dom:
                    local_bytes += page_bytes
                else:
                    remote_bytes += page_bytes
    hbm_bytes = local_bytes + remote_bytes
    t_mem = hbm_bytes / topo.hbm_bw + remote_bytes / max(topo.link_bw * d, 1.0)
    elapsed = max(flops / topo.peak_flops, t_mem)
    return PagedSimResult(
        policy=policy,
        hits=hits,
        misses=misses,
        hbm_bytes=hbm_bytes,
        local_bytes=local_bytes,
        remote_bytes=remote_bytes,
        elapsed=elapsed,
    )


# -----------------------------------------------------------------------------
# Hierarchical tier: device pool LRU backed by a host page store
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class TieredSimResult:
    """One replayed serving trace over the device↔host KV tier."""

    device_hits: int     # page reads served by the device pool
    promotions: int      # reads served by restoring a demoted host page
    demotions: int       # device-capacity evictions that landed host-side
    recomputes: int      # reads absent from both tiers (prefill again)
    link_bytes: int      # device<->host traffic (both directions)
    hbm_bytes: int       # device fills (promotions + recomputes)
    elapsed: float       # seconds: HBM + host-link + recompute terms

    @property
    def device_hit_rate(self) -> float:
        tot = self.device_hits + self.promotions + self.recomputes
        return self.device_hits / tot if tot else 0.0

    @property
    def rescue_rate(self) -> float:
        """Of the reads that missed the device pool, the fraction the host
        tier rescued from recompute — the number tiering exists to move."""
        cold = self.promotions + self.recomputes
        return self.promotions / cold if cold else 0.0


def simulate_tiered_decode(
    access_trace,
    *,
    page_bytes: int,
    device_pages: int,
    host_pages: int,
    topo: Topology,
    recompute_s_per_page: float,
) -> TieredSimResult:
    """Replay a page-access trace through a two-tier LRU: a device pool of
    ``device_pages`` physical pages in front of a host store of
    ``host_pages``. A device miss checks the host tier: resident pages
    *promote* (one page over the host link, then a device fill); absent
    pages *recompute* (``recompute_s_per_page`` — the extend-prefill cost
    the page's tokens would need). Device-capacity evictions *demote*
    into the host LRU instead of vanishing. This is the event-level
    cross-check of ``perf_model.estimate_tier_transfer`` pricing: it sees
    what the analytic form assumes away — host-LRU churn when the cold
    set outgrows ``host_pages``, and promotion ping-pong when the device
    pool is too small for the live working set.

    ``access_trace``: iterable of hashable page keys in read order (e.g.
    ``(head, pid)`` pairs, or chain hashes from a serving trace)."""
    from repro.core import perf_model

    device: OrderedDict = OrderedDict()
    host: OrderedDict = OrderedDict()
    device_hits = promotions = demotions = recomputes = 0
    link_bytes = hbm_bytes = 0
    for key in access_trace:
        if key in device:
            device.move_to_end(key)
            device_hits += 1
            continue
        if key in host:
            del host[key]
            promotions += 1
            link_bytes += page_bytes
        else:
            recomputes += 1
        hbm_bytes += page_bytes
        device[key] = True
        while len(device) > max(device_pages, 1):
            victim, _ = device.popitem(last=False)
            demotions += 1
            link_bytes += page_bytes
            host[victim] = True
            while len(host) > max(host_pages, 0):
                host.popitem(last=False)
    elapsed = (
        hbm_bytes / topo.hbm_bw
        + link_bytes / perf_model.HOST_LINK_BW
        + recomputes * max(recompute_s_per_page, 0.0)
    )
    return TieredSimResult(
        device_hits=device_hits,
        promotions=promotions,
        demotions=demotions,
        recomputes=recomputes,
        link_bytes=link_bytes,
        hbm_bytes=hbm_bytes,
        elapsed=elapsed,
    )


def compare_mappings(
    workload: AttentionWorkload,
    topo: Topology,
    mappings=swizzle.ALL_MAPPINGS,
    *,
    budget_accesses: int = 3_000_000,
    **kw,
) -> Dict[str, SimResult]:
    max_wgs = kw.pop("max_wgs", None)
    if max_wgs is None:
        max_wgs = default_max_wgs(workload, budget_accesses)
    return {m: simulate(m, workload, topo, max_wgs=max_wgs, **kw) for m in mappings}
