"""Attention Compute Clusters (paper §3.1).

An ACC is the set of workgroups that share K/V tensors:
  * MHA: one ACC per (batch, head)            — e.g. DeepSeek-V3 prefill,
  * GQA: one ACC per (batch, kv_head), spanning ``group_size`` query heads
         — e.g. the Llama-3 family (8 KV heads).

The optimization target of the paper is: *co-locate every workgroup of an ACC
in one NUMA domain, and let each domain serve one ACC at a time*.
"""

from __future__ import annotations

import dataclasses

from repro.core.swizzle import AttentionGrid


@dataclasses.dataclass(frozen=True)
class ACCInfo:
    """Footprint of one ACC for cache/bandwidth reasoning."""

    num_wgs: int          # workgroups in the ACC (group_size * blocks_per_head)
    kv_bytes: int         # shared working set: K + V for one kv head
    q_bytes_per_wg: int   # private per-WG operand (one Q row-block)

    def fits_cache(self, cache_bytes: int) -> bool:
        return self.kv_bytes <= cache_bytes


def acc_of(h_q, group_size: int):
    """ACC index of a query head (within one batch element)."""
    return h_q // group_size


def acc_info(
    grid: AttentionGrid,
    *,
    seq_len_kv: int,
    head_dim: int,
    block_m: int,
    dtype_bytes: int = 2,
) -> ACCInfo:
    return ACCInfo(
        num_wgs=grid.group_size * grid.blocks_per_head,
        kv_bytes=2 * seq_len_kv * head_dim * dtype_bytes,
        q_bytes_per_wg=block_m * head_dim * dtype_bytes,
    )


def accs_per_domain(grid: AttentionGrid, num_domains: int) -> float:
    """ACCs each domain must serve over a launch (batch included)."""
    return grid.batch * grid.num_accs / num_domains
