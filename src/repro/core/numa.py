"""NUMA topology descriptors for disaggregated accelerators.

The paper targets AMD MI300X (8 XCD chiplets, each with a private 4 MB L2).
We model any machine whose compute is partitioned into *domains*, each with a
private cache and a set of concurrent execution slots (CUs on a GPU chiplet,
TensorCores on a TPU chip, chips in a TPU pod when the "cache" is HBM).

The same descriptor drives three layers of the system:
  * the cache simulator (``core.cache_sim``) replaying paper configurations,
  * the Pallas kernel grid scheduler (``kernels.flash_attention``) where
    ``num_domains`` is the number of TensorCores sharing HBM (megacore),
  * the mesh-level placement (``core.placement``) where a TPU pod is treated
    as a NUMA machine with one domain per chip.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Topology:
    """A NUMA-ish accelerator: ``num_domains`` domains, private caches.

    Attributes:
      name: human-readable identifier.
      num_domains: number of NUMA domains (XCDs / TensorCores / chips).
      slots_per_domain: concurrent workgroup slots per domain (CUs on an XCD;
        1 for a TPU TensorCore which executes its grid sequentially).
      cache_bytes: private cache capacity per domain (L2 on MI300X; the VMEM
        operand-residency budget on TPU).
      peak_flops: per-*device* peak bf16 FLOP/s (all domains combined).
      hbm_bw: per-device HBM bandwidth, bytes/s.
      link_bw: inter-domain / inter-chip link bandwidth, bytes/s (Infinity
        Fabric per-XCD share on MI300X; a single ICI link on TPU).
    """

    name: str
    num_domains: int
    slots_per_domain: int
    cache_bytes: int
    peak_flops: float
    hbm_bw: float
    link_bw: float

    @property
    def total_slots(self) -> int:
        return self.num_domains * self.slots_per_domain

    @property
    def flops_per_slot(self) -> float:
        return self.peak_flops / self.total_slots

    @property
    def hbm_bw_per_slot(self) -> float:
        return self.hbm_bw / self.total_slots


# --- Presets -----------------------------------------------------------------

#: The paper's evaluation platform (Table 1): 8 XCDs x 38 CUs, 4 MB L2/XCD,
#: 192 GB HBM3 @ 5.3 TB/s, ~1.3 PFLOP/s bf16 peak (MI300X datasheet).
MI300X = Topology(
    name="mi300x",
    num_domains=8,
    slots_per_domain=38,
    cache_bytes=4 * 1024 * 1024,
    peak_flops=1.307e15,
    hbm_bw=5.3e12,
    link_bw=0.75e12,  # per-XCD Infinity-Fabric share (estimate)
)

#: Target hardware for the TPU port. v5e: one TensorCore per chip, so the
#: intra-chip NUMA level is degenerate; the pod level (placement.py) carries
#: the paper's insight. Constants per the assignment brief.
TPU_V5E = Topology(
    name="tpu_v5e",
    num_domains=1,
    slots_per_domain=1,
    cache_bytes=128 * 1024 * 1024,  # VMEM per core
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,  # per ICI link
)

#: v5p-like megacore: two TensorCores sharing one HBM. Pallas splits
#: ``parallel`` grid dimensions across the two cores — the direct analogue of
#: WG->XCD assignment, and the topology under which the swizzle arithmetic is
#: exercised on-chip.
TPU_V5P_MEGACORE = Topology(
    name="tpu_v5p_megacore",
    num_domains=2,
    slots_per_domain=1,
    cache_bytes=128 * 1024 * 1024,
    peak_flops=459e12,
    hbm_bw=2.765e12,
    link_bw=100e9,
)


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Two-tier NUMA: ``num_devices`` chips (each a :class:`Topology` of
    intra-chip domains) joined by an inter-device fabric that is a second,
    slower bandwidth rung above each chip's HBM.

    This is the recursive form of the paper's hierarchy: head -> domain
    inside a chip, head group -> device across the mesh. ``perf_model``
    prices decode placement jointly over (domain, device) with it —
    device-local split-K ranges ride ``chip.hbm_bw`` while ranges that
    straddle devices pay ``device_link_bw`` for the crossing bytes.

    ``device_link_bw`` is the per-device share of the mesh interconnect in
    bytes/s. For TPU chips the preset ``Topology.link_bw`` already *is*
    the chip-to-chip ICI link, so it is the default; platforms whose
    ``link_bw`` means an intra-package fabric (MI300X) should pass the
    inter-GPU figure explicitly.
    """

    chip: Topology
    num_devices: int
    device_link_bw: float

    @property
    def name(self) -> str:
        return f"{self.chip.name}_mesh{self.num_devices}"

    @property
    def total_domains(self) -> int:
        return self.num_devices * self.chip.num_domains

    @property
    def aggregate_hbm_bw(self) -> float:
        return self.num_devices * self.chip.hbm_bw

    @property
    def aggregate_peak_flops(self) -> float:
        return self.num_devices * self.chip.peak_flops


def mesh_topology(
    num_devices: int,
    chip: Topology = TPU_V5E,
    device_link_bw: float | None = None,
) -> MeshTopology:
    """Build the two-tier descriptor for ``num_devices`` chips.

    ``device_link_bw=None`` defaults to ``chip.link_bw`` (the ICI figure
    on the TPU presets) — always a slower rung than ``chip.hbm_bw``."""
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    return MeshTopology(
        chip=chip,
        num_devices=num_devices,
        device_link_bw=(
            chip.link_bw if device_link_bw is None else float(device_link_bw)
        ),
    )


def pod_as_numa(num_chips: int, chip: Topology = TPU_V5E) -> Topology:
    """Treat a TPU pod as a NUMA machine: one domain per chip, HBM as 'cache'.

    Used by ``core.placement`` to reason about ACC-aligned head sharding: a KV
    tensor resident in chip *i*'s HBM is 'remote' to every other chip, exactly
    as an XCD's L2 is invisible to other XCDs.
    """
    return Topology(
        name=f"{chip.name}_pod{num_chips}",
        num_domains=num_chips,
        slots_per_domain=1,
        cache_bytes=16 * 1024**3,  # HBM per v5e chip
        peak_flops=chip.peak_flops * num_chips,
        hbm_bw=chip.hbm_bw * num_chips,
        link_bw=chip.link_bw,
    )
