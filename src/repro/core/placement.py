"""Mesh-level ACC placement: the paper's insight applied to a TPU pod.

Each TPU chip owns private HBM; sharding the head axis over the ``model`` mesh
axis makes every chip a NUMA domain holding a subset of heads' K/V. The
choice the paper studies at WG->XCD granularity recurs verbatim at
head->chip granularity:

  * ``striped`` (naive): q-head h -> shard h % n. A GQA KV group is split
    across ``min(group_size, n)`` shards, so its K/V must be replicated or
    all-gathered — cross-domain traffic, the pod-scale analogue of the
    paper's fragmented L2.
  * ``acc_aligned`` (swizzled): contiguous ranges of whole KV groups per
    shard. Every shard computes attention for its groups entirely from local
    K/V — zero duplication, zero collective inside attention.

`plan()` returns the q/kv head permutations plus the duplication factor, and
`distributed/sharding.py` consumes it when building PartitionSpecs. The
duplication factor feeds the collective-bytes roofline term (§Roofline).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

ACC_ALIGNED = "acc_aligned"
STRIPED = "striped"


@dataclasses.dataclass(frozen=True)
class HeadPlacement:
    """Head -> model-shard assignment for one attention layer family."""

    num_q_heads: int
    num_kv_heads: int
    num_shards: int
    strategy: str
    q_perm: Tuple[int, ...]   # new order of q heads (gather indices)
    kv_perm: Tuple[int, ...]  # new order of kv heads
    kv_duplication: float     # mean #shards holding each kv head (1.0 = ideal)

    @property
    def q_heads_per_shard(self) -> int:
        return self.num_q_heads // self.num_shards

    def shard_of_q_head(self, h: int) -> int:
        """Shard serving (permuted) q-head position h."""
        return h // max(1, self.q_heads_per_shard)


def plan(
    num_q_heads: int,
    num_kv_heads: int,
    num_shards: int,
    strategy: str = ACC_ALIGNED,
) -> HeadPlacement:
    """Compute the head permutation realizing a placement strategy.

    Sharding is always "contiguous blocks of the permuted axis" (that is what
    a PartitionSpec does), so the strategy is encoded entirely in the
    permutation — mirroring how the paper encodes it entirely in the wid
    swizzle while hardware dispatch stays fixed.
    """
    if num_q_heads % num_kv_heads:
        raise ValueError("num_q_heads must be divisible by num_kv_heads")
    group = num_q_heads // num_kv_heads
    n = num_shards

    if strategy == ACC_ALIGNED:
        # Identity: q heads are already laid out group-contiguously
        # (h_kv = h_q // group), so contiguous shards hold whole groups
        # whenever shards divide evenly into groups or vice versa.
        q_perm = np.arange(num_q_heads)
        kv_perm = np.arange(num_kv_heads)
    elif strategy == STRIPED:
        # Round-robin: shard s gets q heads s, s+n, s+2n, ... — the naive
        # baseline. Realized as a permutation placing those heads
        # contiguously so a block-sharded axis reproduces the striping.
        # Stripe width = largest divisor of the head count <= n (fewer heads
        # than shards stripes across all heads).
        def _stripe(count: int) -> np.ndarray:
            eff = max(d for d in range(1, min(n, count) + 1) if count % d == 0)
            return np.arange(count).reshape(-1, eff).T.reshape(-1)

        q_perm = _stripe(num_q_heads)
        kv_perm = _stripe(num_kv_heads)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    # Duplication factor: for each kv head, how many shards host at least one
    # of its q heads. KV must live on (or be gathered to) all of them.
    if num_q_heads % n == 0:
        qps = num_q_heads // n
        shard_of_pos = np.arange(num_q_heads) // qps
    else:
        shard_of_pos = (np.arange(num_q_heads) * n) // num_q_heads
    kv_of_head = q_perm // group  # kv head of the q head at each position
    dup = [
        len(np.unique(shard_of_pos[kv_of_head == kv]))
        for kv in range(num_kv_heads)
    ]
    return HeadPlacement(
        num_q_heads=num_q_heads,
        num_kv_heads=num_kv_heads,
        num_shards=n,
        strategy=strategy,
        q_perm=tuple(int(x) for x in q_perm),
        kv_perm=tuple(int(x) for x in kv_perm),
        kv_duplication=float(np.mean(dup)),
    )


def kv_collective_bytes_per_layer(
    placement: HeadPlacement,
    *,
    seq_len: int,
    head_dim: int,
    batch: int,
    dtype_bytes: int = 2,
) -> float:
    """Extra cross-chip K/V traffic a placement implies, bytes per layer.

    ACC-aligned placement ideally yields 0 (duplication 1.0): each shard's
    attention reads only local K/V. Striped placement must move each KV head
    to (dup - 1) extra shards — an all-gather over the model axis in the
    lowered HLO. This is the pod-scale quantity corresponding to the paper's
    'redundant HBM fetches'.
    """
    kv_bytes = 2 * batch * seq_len * head_dim * dtype_bytes  # K and V, one head
    extra = max(0.0, placement.kv_duplication - 1.0)
    return placement.num_kv_heads * kv_bytes * extra
