"""Workgroup-ID swizzling: the paper's core contribution (Figs. 7-11).

A FlashAttention2 launch is a 1-D grid of ``batch * num_q_heads *
blocks_per_head`` workgroups. The hardware dispatches workgroup ``wid`` to
NUMA domain ``wid % num_domains`` (chunked round-robin, chunk size 1 — paper
§2.2). A *mapping strategy* decides which ``(batch, q_head, q_block)`` cell a
given ``wid`` executes; combined with the fixed hardware policy this fully
determines which domain serves which cell.

The four strategies of paper §3.2-3.3:

  naive_block_first     block-major iteration, no swizzle        (Fig. 7)
  swizzled_block_first  block-major, GQA-group swizzle (AITER)   (Fig. 8)
  naive_head_first      head-major iteration, no swizzle (Triton)(Fig. 9)
  swizzled_head_first   head-major, ACC-aligned swizzle (OURS)   (Fig. 10/11)

All functions here are pure integer arithmetic on ``//``, ``%``, ``*`` so they
evaluate identically on Python ints, numpy arrays and JAX tracers — the same
code feeds the cache simulator, the Pallas ``index_map``s, and the property
tests.

Deviation from paper Fig. 11: the paper interleaves batches at the finest
granularity (``wid_per_batch = wid // BATCH``); we order batch outermost. When
``num_q_heads * blocks_per_head % num_domains == 0`` (all paper configs) the
wid→domain assignment of cells is identical, and the outermost-batch form is
the one a Pallas grid can express directly.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

NAIVE_BLOCK_FIRST = "naive_block_first"
SWIZZLED_BLOCK_FIRST = "swizzled_block_first"
NAIVE_HEAD_FIRST = "naive_head_first"
SWIZZLED_HEAD_FIRST = "swizzled_head_first"

ALL_MAPPINGS = (
    NAIVE_BLOCK_FIRST,
    SWIZZLED_BLOCK_FIRST,
    NAIVE_HEAD_FIRST,
    SWIZZLED_HEAD_FIRST,
)

# KV-sweep traversal within a mapping (Sawtooth Wavefront Reordering,
# PAPERS.md; ROADMAP 5(a)). Orthogonal to the four paper mappings: the
# mapping decides which cell a workgroup computes, the traversal decides
# the *direction* each KV sweep walks its tiles. ``sawtooth`` serpentines
# — even sweeps ascend, odd sweeps descend — so the tile at a sweep
# boundary is shared with the next sweep and its HBM->VMEM copy is
# skipped (Pallas revisiting; on a GPU, the tile is L2-hot).
LINEAR = "linear"
SAWTOOTH = "sawtooth"
TRAVERSALS = (LINEAR, SAWTOOTH)


def kv_tile_order(traversal: str, sweep, n, num_n: int):
    """Effective KV tile index for step ``n`` of sweep ``sweep``.

    ``linear`` walks 0..num_n-1 every sweep; ``sawtooth`` reverses odd
    sweeps (serpentine), so consecutive sweeps meet at a shared boundary
    tile. Pure ``//``/``%``/``*`` arithmetic — evaluates identically on
    Python ints, numpy arrays and JAX tracers (Pallas ``index_map``s).
    """
    if traversal == LINEAR:
        return n
    if traversal != SAWTOOTH:
        raise ValueError(f"unknown traversal {traversal!r}")
    rev = sweep % 2
    return (1 - rev) * n + rev * (num_n - 1 - n)


@dataclasses.dataclass(frozen=True)
class AttentionGrid:
    """Shape of the FA2 workgroup grid for one kernel launch.

    ``group_size`` is the number of query heads sharing one KV head
    (GQA group; 1 for MHA). An Attention Compute Cluster (ACC, paper §3.1) is
    the set of workgroups sharing a KV tensor: ``group_size * blocks_per_head``
    workgroups per (batch, kv_head).
    """

    batch: int
    num_q_heads: int
    blocks_per_head: int
    group_size: int = 1

    def __post_init__(self):
        if self.num_q_heads % self.group_size:
            raise ValueError(
                f"num_q_heads={self.num_q_heads} not divisible by "
                f"group_size={self.group_size}"
            )

    @property
    def num_kv_heads(self) -> int:
        return self.num_q_heads // self.group_size

    @property
    def wgs_per_batch(self) -> int:
        return self.num_q_heads * self.blocks_per_head

    @property
    def total_wgs(self) -> int:
        return self.batch * self.wgs_per_batch

    @property
    def num_accs(self) -> int:
        """ACCs per batch element: one per KV head."""
        return self.num_kv_heads


def domain_of(wid, num_domains: int):
    """Hardware dispatch policy: chunked round-robin with chunk size 1."""
    return wid % num_domains


def _heads_per_domain(num_q_heads: int, num_domains: int) -> int:
    """Paper assumes H % D == 0; we round up and wrap for the general case."""
    return max(1, -(-num_q_heads // num_domains))


def decode(mapping: str, wid, grid: AttentionGrid, num_domains: int):
    """Map a linear workgroup id to its ``(batch, q_head, q_block)`` cell.

    This is the inverse view of the paper's swizzles: given the wid the
    hardware hands us (and hence the domain ``wid % num_domains`` we run on),
    which cell should we compute so that the *set of cells per domain* matches
    the strategy's intent.
    """
    wpb = grid.wgs_per_batch
    b = wid // wpb
    r = wid % wpb
    h_count = grid.num_q_heads
    m_count = grid.blocks_per_head
    d = num_domains

    if mapping == NAIVE_BLOCK_FIRST:
        # for block m: for head h: wid++  => XCD_i gets block0 of head i, ...
        h = r % h_count
        m = r // h_count
    elif mapping == SWIZZLED_BLOCK_FIRST:
        # Block-major within each domain, contiguous head ranges per domain
        # (AITER): domain d serves heads [d*hpx, (d+1)*hpx), iterating
        # block-first across them.
        hpx = _heads_per_domain(h_count, d)
        dom = r % d
        slot = r // d
        h = (dom * hpx + slot % hpx) % h_count
        m = (slot // hpx) % m_count
    elif mapping == NAIVE_HEAD_FIRST:
        # All blocks of head 0, then head 1, ... (Triton default); round-robin
        # dispatch stripes each head across every domain.
        h = r // m_count
        m = r % m_count
    elif mapping == SWIZZLED_HEAD_FIRST:
        # Paper Fig. 11: domain d serves heads [d*hpx, (d+1)*hpx) one full
        # head at a time, blocks in order within the head.
        hpx = _heads_per_domain(h_count, d)
        dom = r % d
        h = (dom * hpx + r // (d * m_count)) % h_count
        m = (r % (d * m_count)) // d
    else:
        raise ValueError(f"unknown mapping {mapping!r}")
    return b, h, m


def encode(mapping: str, b, h, m, grid: AttentionGrid, num_domains: int):
    """Inverse of :func:`decode` (exists when H % D == 0 and M % ... aligns).

    Only used by tests (bijectivity property) and the placement planner.
    """
    wpb = grid.wgs_per_batch
    h_count = grid.num_q_heads
    m_count = grid.blocks_per_head
    d = num_domains

    if mapping == NAIVE_BLOCK_FIRST:
        r = m * h_count + h
    elif mapping == SWIZZLED_BLOCK_FIRST:
        hpx = _heads_per_domain(h_count, d)
        dom = h // hpx
        slot = m * hpx + h % hpx
        r = slot * d + dom
    elif mapping == NAIVE_HEAD_FIRST:
        r = h * m_count + m
    elif mapping == SWIZZLED_HEAD_FIRST:
        hpx = _heads_per_domain(h_count, d)
        dom = h // hpx
        r = (h % hpx) * (d * m_count) + m * d + dom
    else:
        raise ValueError(f"unknown mapping {mapping!r}")
    return b * wpb + r


def heads_per_domain_sets(
    mapping: str, grid: AttentionGrid, num_domains: int
) -> Tuple[set, ...]:
    """Which q-heads each domain touches (batch 0). Used by tests/benchmarks.

    The paper's co-location property: under ``swizzled_head_first`` each
    domain's set is a contiguous range of ``H/D`` heads — whole ACCs.
    """
    import numpy as np

    wids = np.arange(grid.wgs_per_batch)
    _, h, _ = decode(mapping, wids, grid, num_domains)
    doms = domain_of(wids, num_domains)
    return tuple(
        set(np.unique(h[doms == dom]).tolist()) for dom in range(num_domains)
    )


def accs_per_domain_concurrent(
    mapping: str, grid: AttentionGrid, num_domains: int, window: int
) -> float:
    """Mean number of *distinct ACCs* live in a domain's dispatch window.

    ``window`` models the number of concurrently resident workgroups per
    domain (38 CUs on an MI300X XCD). This is the quantity the paper's L2
    argument is about: 1 distinct ACC per window => one shared KV stream =>
    hits; ``window`` distinct ACCs => thrash.
    """
    import numpy as np

    wids = np.arange(grid.total_wgs)
    b, h, _ = decode(mapping, wids, grid, num_domains)
    doms = domain_of(wids, num_domains)
    acc = b * grid.num_kv_heads + h // grid.group_size
    counts = []
    for dom in range(num_domains):
        stream = acc[doms == dom]
        for i in range(0, len(stream) - window + 1, window):
            counts.append(len(np.unique(stream[i : i + window])))
    return float(np.mean(counts)) if counts else 0.0
