"""Core: the paper's contribution — NUMA-aware attention scheduling.

Modules:
  swizzle     four workgroup mapping strategies (paper Figs. 7-11)
  acc         Attention Compute Cluster abstraction (paper §3.1)
  numa        NUMA topology descriptors (MI300X, TPU presets)
  cache_sim   event-driven multi-domain LRU simulator (paper §4 evaluation)
  perf_model  analytic hit-rate / throughput model
  placement   mesh-level ACC-aligned head sharding (TPU-pod adaptation)
"""

from repro.core import acc, cache_sim, numa, perf_model, placement, swizzle  # noqa: F401
from repro.core.swizzle import (  # noqa: F401
    ALL_MAPPINGS,
    NAIVE_BLOCK_FIRST,
    NAIVE_HEAD_FIRST,
    SWIZZLED_BLOCK_FIRST,
    SWIZZLED_HEAD_FIRST,
    AttentionGrid,
)
