"""Analytic performance / hit-rate model, cross-validated against cache_sim.

Two uses:
  1. a fast path for the benchmark sweeps (the event simulator is exact but
     slow at paper scale; the analytic model is O(1) per config),
  2. napkin math for the §Perf hillclimb — predicted deltas before a change.

Model (per domain, steady state):
  Let ``w`` = concurrent workgroup slots per domain, ``a`` = mean distinct
  ACCs among the ``w`` resident workgroups (from the dispatch order of the
  mapping), ``R`` = reuse window in bytes that the cache must retain for
  concurrent sharers to hit (tile size x drift distance x streams).

  * If the *whole shared working set* of the resident ACCs fits in cache
    (short sequences), everything after cold misses hits:
        hit_rate ~= 1 - cold/accesses.
  * Else sharing is stream-wise: of each group of ``w/a`` workgroups walking
    one KV stream, the leader misses and the rest hit — provided the group's
    drift window fits in cache:
        hit_rate ~= 1 - a / w      (fits)
        hit_rate ~= 0              (thrash: a distinct streams overflow)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import acc as acc_lib
from repro.core import swizzle
from repro.core.cache_sim import AttentionWorkload
from repro.core.numa import MeshTopology, Topology


@dataclasses.dataclass(frozen=True)
class AnalyticEstimate:
    mapping: str
    hit_rate: float
    time: float          # seconds per launch (model)
    hbm_bytes: float
    flops: float

    @property
    def throughput(self) -> float:
        return self.flops / self.time if self.time else 0.0


def _mean_kv_tiles(wl: AttentionWorkload) -> float:
    blocks = -(-wl.seq_len // wl.block_m)
    if not wl.causal:
        return float(wl.kv_tiles_total)
    return (blocks + 1) * wl.block_m / (2.0 * wl.block_n)


def estimate(
    mapping: str, wl: AttentionWorkload, topo: Topology, *, drift_tiles: int = 16
) -> AnalyticEstimate:
    blocks = -(-wl.seq_len // wl.block_m)
    grid = dataclasses.replace(wl.grid, blocks_per_head=blocks)
    w = topo.slots_per_domain
    a = swizzle.accs_per_domain_concurrent(mapping, grid, topo.num_domains, w)
    a = max(a, 1.0)

    info = acc_lib.acc_info(
        grid,
        seq_len_kv=wl.seq_len,
        head_dim=wl.head_dim,
        block_m=wl.block_m,
        dtype_bytes=wl.dtype_bytes,
    )
    mean_tiles = _mean_kv_tiles(wl)
    accesses_per_wg = 1 + 2 * mean_tiles
    total_wgs = grid.total_wgs
    accesses = total_wgs * accesses_per_wg

    if a * info.kv_bytes <= topo.cache_bytes:
        # Resident regime: each domain cold-loads its ACCs' KV once.
        unique_accs = grid.batch * grid.num_accs
        cold = unique_accs * (2 * wl.kv_tiles_total) / max(topo.num_domains, 1)
        # naive mappings replicate ACCs across all domains:
        if mapping in (swizzle.NAIVE_HEAD_FIRST, swizzle.NAIVE_BLOCK_FIRST):
            cold *= topo.num_domains
        hit = max(0.0, 1.0 - cold * topo.num_domains / accesses)
    else:
        # Streaming regime: leader-miss / follower-hit within each stream,
        # if the drift window of `a` concurrent streams fits in cache.
        window_bytes = a * drift_tiles * 2 * wl.kv_tile_bytes * (w / a)
        if window_bytes <= topo.cache_bytes:
            hit = max(0.0, 1.0 - a / w)
        else:
            hit = 0.02  # residual (Q tiles, boundary reuse)

    flops = total_wgs * mean_tiles * wl.flops_per_tile_pair
    hbm_bytes = (1 - hit) * accesses * 2 * wl.kv_tile_bytes
    t_compute = flops / topo.peak_flops
    t_mem = hbm_bytes / topo.hbm_bw
    return AnalyticEstimate(
        mapping=mapping,
        hit_rate=hit,
        time=max(t_compute, t_mem),
        hbm_bytes=hbm_bytes,
        flops=flops,
    )


# -----------------------------------------------------------------------------
# Decode-over-KV-cache estimates: dense stripes vs paged pools
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeEstimate:
    """Analytic model of one decode tick (one new token per sequence)."""

    layout: str          # "dense" | "paged:head_aligned" | "paged:interleaved"
    time: float          # seconds per tick
    hbm_bytes: float     # bytes filled from memory (after domain-level reuse)
    link_bytes: float    # bytes crossing the inter-domain fabric
    flops: float
    reuse_rate: float    # fraction of page reads served by domain reuse

    @property
    def tokens_per_second(self) -> float:
        return 1.0 / self.time if self.time else 0.0


def estimate_dense_decode(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    capacity: int,
    head_dim: int,
    dtype_bytes: int,
    topo: Topology,
) -> DecodeEstimate:
    """Dense per-slot stripes: every (batch, kv-head) cell streams its whole
    ``capacity``-token stripe — the pipeline copies every chunk regardless
    of the live length (masking skips compute, not traffic). This is the
    cost the paged layout exists to avoid."""
    kv_bytes = 2.0 * batch * num_kv_heads * capacity * head_dim * dtype_bytes
    flops = 4.0 * batch * num_q_heads * capacity * head_dim
    t = max(flops / topo.peak_flops, kv_bytes / topo.hbm_bw)
    return DecodeEstimate(
        layout="dense", time=t, hbm_bytes=kv_bytes, link_bytes=0.0,
        flops=flops, reuse_rate=0.0,
    )


def estimate_paged_decode(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    mean_len: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    topo: Topology,
    policy: str = "head_aligned",
    shared_prefix_len: int = 0,
) -> DecodeEstimate:
    """Paged pool: each cell walks only its live pages; the first
    ``shared_prefix_len`` tokens are one set of physical pages shared by all
    ``batch`` sequences, fetched once per owning domain and then reused.

    ``head_aligned`` placement keeps every page in its cell's domain (all
    local; a shared page occupies exactly one domain's cache).
    ``interleaved`` stripes pages round-robin, so ``(d-1)/d`` of the bytes
    cross the fabric — the modeled cost ``cache.layout`` assigns the naive
    allocator. Matches ``cache.layout.decode_page_traffic`` on the uniform
    trace by construction (cross-checked in tests)."""
    from repro.cache import layout as layout_lib

    d = max(topo.num_domains, 1)
    page_bytes = 2.0 * page_size * head_dim * dtype_bytes
    live_pages = -(-mean_len // page_size)
    shared_pages = min(shared_prefix_len // page_size, live_pages)
    private_pages = live_pages - shared_pages

    # Per kv head: private pages fetched once per sequence; shared pages
    # fetched once per tick (every head lives in exactly one domain under
    # the head-first grid, so domain-level reuse collapses the batch).
    fetches = num_kv_heads * (batch * private_pages + shared_pages)
    reads = num_kv_heads * batch * live_pages
    hbm_bytes = fetches * page_bytes
    if policy == layout_lib.HEAD_ALIGNED:
        link_bytes = 0.0
    elif policy == layout_lib.INTERLEAVED:
        link_bytes = hbm_bytes * (d - 1) / d
    else:
        raise ValueError(f"unknown page placement policy {policy!r}")

    flops = 4.0 * batch * num_q_heads * mean_len * head_dim
    t_mem = hbm_bytes / topo.hbm_bw + link_bytes / max(topo.link_bw * d, 1.0)
    t = max(flops / topo.peak_flops, t_mem)
    return DecodeEstimate(
        layout=f"paged:{policy}", time=t, hbm_bytes=hbm_bytes,
        link_bytes=link_bytes, flops=flops,
        reuse_rate=1.0 - fetches / reads if reads else 0.0,
    )


# -----------------------------------------------------------------------------
# Split-K decode: occupancy-driven split selection (PR 4)
# -----------------------------------------------------------------------------

#: Fixed cost charged for the split-combine stage: the second (tiny) launch
#: plus its scheduling latency. Charged once whenever num_splits > 1.
COMBINE_LAUNCH_OVERHEAD_S = 2e-6

#: Modeled host-side cost of one decode sync: dispatch of the jitted step,
#: device->host transfer of the sampled tokens, and the Python bookkeeping
#: (stop scan, page-table upkeep, output flush) before the next launch.
#: This is the per-token tax the fused multi-step scan amortizes.
HOST_SYNC_OVERHEAD_S = 50e-6


def amortized_host_overhead(steps_per_sync: int) -> float:
    """Modeled per-token host overhead when the engine syncs once per
    ``steps_per_sync`` fused scan ticks: the fixed :data:`HOST_SYNC_OVERHEAD_S`
    is paid once per sync and spread over the N tokens it produced."""
    return HOST_SYNC_OVERHEAD_S / max(int(steps_per_sync), 1)


#: Device<->host page-transfer bandwidth (B/s) for the hierarchical KV
#: tier: PCIe-Gen4-x16-class (~32 GB/s sustained), i.e. one to two orders
#: below HBM but vastly above "recompute the prefill behind the page".
#: The tiering backend prices demotion/promotion against recompute with
#: this — the same honesty contract the decode estimates follow.
HOST_LINK_BW = 32e9


def estimate_tier_transfer(nbytes: int) -> float:
    """Modeled seconds to move ``nbytes`` of demoted/promoted KV pages
    across the device<->host link, charged one host sync for the
    round-trip dispatch. Linear in bytes: page payloads are large
    contiguous copies, so latency is sync-dominated only for tiny runs."""
    return HOST_SYNC_OVERHEAD_S + max(int(nbytes), 0) / HOST_LINK_BW


def tier_transfer_beats_recompute(nbytes: int, recompute_s: float) -> bool:
    """The demote-vs-preempt policy question in one predicate: is
    restoring ``nbytes`` of pages over the host link modeled faster than
    recomputing them (``recompute_s``, e.g. the extend-prefill delta)?
    True is the normal case — page transfer is orders of magnitude
    cheaper than re-prefilling the tokens behind it; False flags shapes
    (tiny prefixes) where eviction-and-recompute is honest."""
    return estimate_tier_transfer(nbytes) < max(recompute_s, 0.0)

#: Default cap on the split sweep. The model plateaus well before this on
#: every topology we carry (waves stop shrinking once cells x splits covers
#: the domains, and the combine term grows linearly), so the cap only
#: bounds the candidate loop.
MAX_DECODE_SPLITS = 16


@dataclasses.dataclass(frozen=True)
class SplitEstimate:
    """Occupancy model of split-K decode for one shape: the chosen split
    count, its modeled time, the one-pass baseline, and the full sweep.

    When the estimate was scored against a :class:`~repro.core.numa.
    MeshTopology` (``mesh`` passed to :func:`estimate_decode_splits`),
    ``device_pure`` records the joint (domain, device) placement verdict:
    True means every split range stays inside the device owning its KV
    head (all streaming rides local HBM), False means striping the ranges
    across devices — paying the inter-device link for ``(D-1)/D`` of the
    bytes — still modeled faster (only possible when the link rivals HBM
    or the head count leaves device HBM idle). ``None`` on single-device
    estimates, where the question does not arise."""

    num_splits: int
    time: float                      # modeled tick seconds at num_splits
    base_time: float                 # num_splits == 1 baseline
    times: Tuple[Tuple[int, float], ...]  # the whole candidate sweep
    device_pure: Optional[bool] = None   # mesh: device-local ranges won?
    num_devices: int = 1

    @property
    def speedup(self) -> float:
        return self.base_time / self.time if self.time else 0.0


def estimate_decode_splits(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_kv: int,
    granule: int,
    head_dim: int,
    dtype_bytes: int,
    topo: Topology,
    window: Optional[int] = None,
    max_splits: int = MAX_DECODE_SPLITS,
    mesh: Optional[MeshTopology] = None,
) -> SplitEstimate:
    """Pick ``num_splits`` for a flash-decode launch by occupancy.

    A decode tick exposes only ``cells = batch x num_kv_heads`` parallel
    grid cells (the GQA group rides inside a cell); on a machine with
    more NUMA domains than cells most of the chip idles while one cell
    streams its whole KV serially. Splitting the KV walk into ``s``
    ranges multiplies the cell count by ``s`` at the price of a combine
    pass over the partial states. Modeled per candidate ``s``:

      * each (cell, split) streams ``kv_bytes / s`` from its domain's HBM
        share and runs ``flops / s`` on its domain's compute share
        (``granule``-sized units: pages for the paged kernel, KV chunks
        for the dense one — ``s`` is capped at the unit count so a split
        is never empty by construction);
      * the launch executes in ``waves = ceil(cells * s / num_domains)``
        rounds — the occupancy term: splitting only wins while extra
        splits still land on idle domains;
      * ``s > 1`` is charged the combine explicitly: the fp32 partial
        ``(acc, m, l)`` states written by stage one and re-read by the
        combine, plus :data:`COMBINE_LAUNCH_OVERHEAD_S`.

    A sliding window bounds the *live* KV (flops and the useful split
    count) without reducing streamed bytes — the pipeline copies every
    unit regardless; relevance only gates compute. Splitting still pays
    off under a window because the cost being parallelized IS the
    streaming: each split cell DMAs only its range (``kv_bytes / s``)
    even when all its positions are masked, so the bandwidth term — which
    dominates decode — genuinely divides by ``s``; only the (negligible)
    compute concentrates in the window-holding splits. Capping the
    candidate count at the live unit count keeps the choice conservative.

    With ``mesh`` (the inter-device bandwidth tier) each candidate ``s``
    is additionally scored under both joint (domain, device) placements:

      * **device-pure** — every split range of a cell stays on the device
        owning the cell's KV head (the head-sharded pool): all streaming
        is local HBM, the combine is local, but only ``min(Hkv, D)``
        devices' HBM supplies bytes;
      * **straddled** — ranges stripe round-robin across all ``D``
        devices (the device-tier analogue of ``interleaved`` page
        placement): every device's HBM supplies bytes, at the price of
        ``(D-1)/D`` of the KV — and the combine's partial states —
        crossing ``device_link_bw``.

    Device-pure wins whenever the head count covers the devices (equal
    supply, zero link cost); straddling can only win when heads leave
    device HBM idle (``Hkv < D``) *and* the link rivals HBM — both
    directions are pinned in tests. Ties keep device-pure.
    """
    cells = max(1, batch * num_kv_heads)
    group = max(1, num_q_heads // max(num_kv_heads, 1))
    domains = max(1, topo.num_domains)
    live = min(seq_kv, window) if (window and window > 0) else seq_kv
    units = max(1, -(-int(live) // max(int(granule), 1)))

    kv_bytes = 2.0 * seq_kv * head_dim * dtype_bytes        # per cell, K + V
    flops = 4.0 * group * live * head_dim                   # per cell
    bw_dom = topo.hbm_bw / domains
    fl_dom = topo.peak_flops / domains
    gp = max(8, -(-group // 8) * 8)
    # Partial state per (cell, split): fp32 acc (gp x d) + m + l (gp x 1
    # each), written once and read once by the combine.
    state_bytes = 2 * 4.0 * gp * (head_dim + 2)

    num_devices = mesh.num_devices if mesh is not None else 1
    link_bw = mesh.device_link_bw if mesh is not None else 0.0

    def candidate(s: int, pure: bool) -> float:
        if pure:
            # Device-pure: every range streams its owner's local HBM.
            # Only ``min(Hkv, D)`` devices' HBM supplies bytes (head
            # ownership), and each supplier runs its share in waves over
            # its own domains. The aggregate-supply term is always <= the
            # wave term at D == 1, so the single-device model is exactly
            # the PR-4 formula.
            owners = min(max(num_kv_heads, 1), num_devices)
            supply = -(-cells * s // owners)   # split units per supplier
            waves = -(-supply // domains)
            t = max(
                waves * max(kv_bytes / s / bw_dom, flops / s / fl_dom),
                cells * kv_bytes / (topo.hbm_bw * owners),
            )
        else:
            # Straddled: ranges stripe round-robin over all D devices'
            # pools (interleaved placement, one tier up). A unit pulls
            # its pages from D HBMs in parallel through its device link,
            # so its stream rate is min(link, D x domain share); the
            # aggregate caps are all-device HBM supply and the fabric
            # carrying the (D-1)/D remote fraction.
            owners = num_devices
            rate = min(max(link_bw, 1.0), num_devices * bw_dom)
            waves = -(-cells * s // (num_devices * domains))
            t = max(
                waves * max(kv_bytes / s / rate, flops / s / fl_dom),
                cells * kv_bytes / (topo.hbm_bw * num_devices),
                cells * kv_bytes * (num_devices - 1) / num_devices
                / max(link_bw * num_devices, 1.0),
            )
        if s > 1:
            t += cells * s * state_bytes / (topo.hbm_bw * owners)
            t += COMBINE_LAUNCH_OVERHEAD_S
            if not pure:
                # Partial states cross the fabric to the combining owner.
                t += cells * s * state_bytes \
                    * (num_devices - 1) / num_devices \
                    / max(link_bw * num_devices, 1.0)
        return t

    times = []
    best = None  # (time, s, device_pure)
    for s in range(1, max(1, min(int(max_splits), units)) + 1):
        placements = (True,) if num_devices <= 1 else (True, False)
        t_s = None
        for pure in placements:   # pure first: strict < keeps it on ties
            t = candidate(s, pure)
            if t_s is None or t < t_s:
                t_s = t
            if best is None or t < best[0]:
                best = (t, s, pure)
        times.append((s, t_s))
    return SplitEstimate(
        num_splits=best[1],
        time=best[0],
        base_time=times[0][1],
        times=tuple(times),
        device_pure=(best[2] if num_devices > 1 else None),
        num_devices=num_devices,
    )


def estimate_sharded_paged_decode(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    mean_len: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    mesh: MeshTopology,
    shared_prefix_len: int = 0,
) -> DecodeEstimate:
    """One decode tick with the page pool KV-head-sharded over the mesh.

    Each device runs :func:`estimate_paged_decode` over its head slice
    (``ceil(Hkv / D)`` heads — the contiguous blocks ``cache.layout.
    device_of_head`` hands out) against its own chip topology; the tick
    finishes when the busiest device does. With replicated parameters the
    only cross-device traffic in the modeled hot loop is the attention
    outputs' gather (``B x Hq_slice x hd`` per non-owner device per
    layer-equivalent — charged once against the link); the KV streaming
    itself is entirely device-local, which is the point of the sharding.
    Aggregate tokens/s is ``batch / time`` — the modeled scaling curve the
    loadgen sharded artifact records next to the measured one."""
    d = max(mesh.num_devices, 1)
    heads_dev = -(-max(num_kv_heads, 1) // d)
    q_heads_dev = -(-max(num_q_heads, 1) // d)
    local = estimate_paged_decode(
        batch=batch, num_q_heads=q_heads_dev, num_kv_heads=heads_dev,
        mean_len=mean_len, page_size=page_size, head_dim=head_dim,
        dtype_bytes=dtype_bytes, topo=mesh.chip,
        shared_prefix_len=shared_prefix_len,
    )
    # Attention-output gather: every device contributes its head slice of
    # the (B, Hq, hd) activations to the replicated residual stream.
    gather_bytes = (
        batch * q_heads_dev * head_dim * dtype_bytes * (d - 1)
        if d > 1 else 0.0
    )
    t = local.time + gather_bytes / max(mesh.device_link_bw * d, 1.0)
    return DecodeEstimate(
        layout=f"{local.layout}:mesh{d}",
        time=t,
        hbm_bytes=local.hbm_bytes * d,
        link_bytes=gather_bytes,
        flops=local.flops * d,
        reuse_rate=local.reuse_rate,
    )


#: Cap on the adaptive steps-per-sync chooser. Powers of two up to this
#: bound the fused-decode jit keys at O(log MAX) per engine — the
#: zero-steady-state-retrace guarantee survives adaptivity.
MAX_STEPS_PER_SYNC = 32


def choose_steps_per_sync(
    *,
    decode_tick_s: float,
    max_steps: int = MAX_STEPS_PER_SYNC,
    overhead_budget: float = 0.1,
) -> int:
    """Pick the fused scan length N from the modeled decode tick time.

    The smallest power of two whose amortized per-token host overhead
    (:func:`amortized_host_overhead`) drops below ``overhead_budget`` of
    the tick itself, capped at ``max_steps``. Deep batches / long
    contexts have expensive ticks, so the sync tax is already noise and N
    stays small (host visibility every token); tiny ticks drown in the
    50 µs sync and N climbs toward the cap. Restricting N to powers of
    two keeps the scan launcher's jit-key count logarithmic."""
    n = 1
    cap = max(1, int(max_steps))
    while n < cap and amortized_host_overhead(n) \
            > overhead_budget * max(decode_tick_s, 0.0):
        n *= 2
    return min(n, cap)


def estimate_extend_prefill(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    prefix_len: int,
    tail_len: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    topo: Topology,
    policy: str = "head_aligned",
    gather: bool = False,
) -> DecodeEstimate:
    """Prefix-extension prefill: ``tail_len`` new queries attending a
    ``prefix_len``-token paged prefix plus their own causal tail.

    ``gather=False`` models the paged prefill kernel: each (batch, kv-head)
    grid cell streams the prefix's pages exactly once (the whole GQA group
    rides in the q block) plus the tail K/V. ``gather=True`` models the
    legacy route the kernel replaces: the pages are read *and written back*
    as a dense copy, which the dense flash path then reads again — ~3x the
    prefix bytes, before any fabric cost.

    Both routes are charged **occupancy** (PR 4): the paged kernel's grid
    exposes only ``batch x num_kv_heads`` parallel cells (its page walk
    and tail steps are sequential inside a cell), while the gather
    route's dense flash fans out over ``batch x num_q_heads x tail
    q-blocks``. Each route's effective bandwidth/compute is its occupied
    share ``min(1, cells / num_domains)`` of the chip — so at low
    ``B x Hkv`` (MQA, single-request admission) the gather route's extra
    prefix bytes can be cheaper than leaving domains idle, and the plan
    layer picks the route per shape on exactly this estimate."""
    from repro.cache import layout as layout_lib

    d = max(topo.num_domains, 1)
    page_bytes = 2.0 * page_size * head_dim * dtype_bytes
    prefix_pages = -(-prefix_len // page_size)
    prefix_bytes = batch * num_kv_heads * prefix_pages * page_bytes
    tail_bytes = 2.0 * batch * num_kv_heads * tail_len * head_dim * dtype_bytes
    q_bytes = 2.0 * batch * num_q_heads * tail_len * head_dim * dtype_bytes
    if policy not in (layout_lib.HEAD_ALIGNED, layout_lib.INTERLEAVED):
        raise ValueError(f"unknown page placement policy {policy!r}")
    if policy == layout_lib.HEAD_ALIGNED and not gather:
        link_bytes = 0.0
    else:
        # Interleaved placement — or gathering to a dense stripe, which
        # forfeits head-alignment: the copy lands wherever the allocator
        # put the dense buffer.
        link_bytes = prefix_bytes * (d - 1) / d
    # Causal tail: each query row scores prefix_len + ~half the tail.
    flops = 4.0 * batch * num_q_heads * tail_len * (
        prefix_len + tail_len / 2.0
    ) * head_dim
    t_link = link_bytes / max(topo.link_bw * d, 1.0)
    if gather:
        hbm_bytes = 3.0 * prefix_bytes + tail_bytes + q_bytes
        # The gather copy (read + write the prefix) is an embarrassingly
        # parallel memcpy at full chip occupancy; the dense flash that
        # follows re-reads the prefix and fans out over q blocks.
        flash_cells = batch * num_q_heads * max(1, -(-tail_len // 128))
        occ = min(1.0, flash_cells / d)
        t_copy = 2.0 * prefix_bytes / topo.hbm_bw + t_link
        flash_bytes = prefix_bytes + tail_bytes + q_bytes
        t = t_copy + max(
            flops / (topo.peak_flops * occ),
            flash_bytes / (topo.hbm_bw * occ),
        )
    else:
        hbm_bytes = prefix_bytes + tail_bytes + q_bytes
        occ = min(1.0, (batch * num_kv_heads) / d)
        t_mem = hbm_bytes / (topo.hbm_bw * occ) + t_link
        t = max(flops / (topo.peak_flops * occ), t_mem)
    # Reuse = fraction of logical prefix reads (one per q-head: the GQA
    # group shares each page) served without a physical fetch — the same
    # convention as estimate_paged_decode. The gather route's 3x prefix
    # traffic eats into it; it can go to 0, never negative.
    group = max(1, num_q_heads // max(num_kv_heads, 1))
    logical = group * prefix_bytes
    fetched = prefix_bytes * (3.0 if gather else 1.0)
    return DecodeEstimate(
        layout=f"extend:{'gather' if gather else 'paged'}",
        time=t, hbm_bytes=hbm_bytes, link_bytes=link_bytes, flops=flops,
        reuse_rate=max(0.0, 1.0 - fetched / logical) if logical else 0.0,
    )


def estimate_attention_plan(
    plan,
    shape,
    *,
    topo: Topology,
    dtype_bytes: int = 2,
):
    """Score an :class:`~repro.kernels.plan.AttentionPlan` for a shape —
    the single scoring entry point the plan layer and the benchmarks share.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, seq_q, seq_kv,
    head_dim)`` (the plan's own convention). Dispatches on phase/layout:
    prefill -> :func:`estimate` of the plan's mapping; dense decode ->
    :func:`estimate_dense_decode`; paged decode ->
    :func:`estimate_paged_decode`; paged extend ->
    :func:`estimate_extend_prefill` (gather-modeled when the plan fell off
    the kernel path)."""
    from repro.core.cache_sim import AttentionWorkload
    from repro.core.swizzle import AttentionGrid

    b, hq, hkv, sq, skv, hd = (int(x) for x in shape)
    if plan.phase == "decode":
        if plan.kv_layout == "paged":
            return estimate_paged_decode(
                batch=b, num_q_heads=hq, num_kv_heads=hkv, mean_len=skv,
                page_size=plan.page_size, head_dim=hd,
                dtype_bytes=dtype_bytes, topo=topo,
                policy=plan.placement or "head_aligned",
            )
        return estimate_dense_decode(
            batch=b, num_q_heads=hq, num_kv_heads=hkv, capacity=skv,
            head_dim=hd, dtype_bytes=dtype_bytes, topo=topo,
        )
    if plan.phase == "extend" and plan.kv_layout == "paged":
        return estimate_extend_prefill(
            batch=b, num_q_heads=hq, num_kv_heads=hkv,
            prefix_len=skv - sq, tail_len=sq, page_size=plan.page_size,
            head_dim=hd, dtype_bytes=dtype_bytes, topo=topo,
            policy=plan.placement or "head_aligned",
            gather=plan.impl != "pallas",
        )
    # prefill (and the dense-extend oracle): the mapping's analytic model.
    mc = plan.mapping
    name = ("swizzled_" if mc.acc_parallel else "naive_") + mc.order
    grid = AttentionGrid(
        batch=b, num_q_heads=hq,
        blocks_per_head=-(-skv // mc.block_m),
        group_size=max(1, hq // max(hkv, 1)),
    )
    wl = AttentionWorkload(
        grid=grid, seq_len=skv, head_dim=hd,
        block_m=mc.block_m, block_n=mc.block_n,
        causal=True, dtype_bytes=dtype_bytes,
    )
    return estimate(name, wl, topo)


def relative_performance(
    wl: AttentionWorkload,
    topo: Topology,
    baseline: str = swizzle.SWIZZLED_HEAD_FIRST,
    mappings=swizzle.ALL_MAPPINGS,
) -> Dict[str, float]:
    """Throughput of each mapping relative to the baseline (paper Figs 12/14/15)."""
    ests = {m: estimate(m, wl, topo) for m in mappings}
    base = ests[baseline].throughput
    return {m: (e.throughput / base if base else 0.0) for m, e in ests.items()}
