"""Analytic performance / hit-rate model, cross-validated against cache_sim.

Two uses:
  1. a fast path for the benchmark sweeps (the event simulator is exact but
     slow at paper scale; the analytic model is O(1) per config),
  2. napkin math for the §Perf hillclimb — predicted deltas before a change.

Model (per domain, steady state):
  Let ``w`` = concurrent workgroup slots per domain, ``a`` = mean distinct
  ACCs among the ``w`` resident workgroups (from the dispatch order of the
  mapping), ``R`` = reuse window in bytes that the cache must retain for
  concurrent sharers to hit (tile size x drift distance x streams).

  * If the *whole shared working set* of the resident ACCs fits in cache
    (short sequences), everything after cold misses hits:
        hit_rate ~= 1 - cold/accesses.
  * Else sharing is stream-wise: of each group of ``w/a`` workgroups walking
    one KV stream, the leader misses and the rest hit — provided the group's
    drift window fits in cache:
        hit_rate ~= 1 - a / w      (fits)
        hit_rate ~= 0              (thrash: a distinct streams overflow)
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import acc as acc_lib
from repro.core import swizzle
from repro.core.cache_sim import AttentionWorkload
from repro.core.numa import Topology


@dataclasses.dataclass(frozen=True)
class AnalyticEstimate:
    mapping: str
    hit_rate: float
    time: float          # seconds per launch (model)
    hbm_bytes: float
    flops: float

    @property
    def throughput(self) -> float:
        return self.flops / self.time if self.time else 0.0


def _mean_kv_tiles(wl: AttentionWorkload) -> float:
    blocks = -(-wl.seq_len // wl.block_m)
    if not wl.causal:
        return float(wl.kv_tiles_total)
    return (blocks + 1) * wl.block_m / (2.0 * wl.block_n)


def estimate(
    mapping: str, wl: AttentionWorkload, topo: Topology, *, drift_tiles: int = 16
) -> AnalyticEstimate:
    blocks = -(-wl.seq_len // wl.block_m)
    grid = dataclasses.replace(wl.grid, blocks_per_head=blocks)
    w = topo.slots_per_domain
    a = swizzle.accs_per_domain_concurrent(mapping, grid, topo.num_domains, w)
    a = max(a, 1.0)

    info = acc_lib.acc_info(
        grid,
        seq_len_kv=wl.seq_len,
        head_dim=wl.head_dim,
        block_m=wl.block_m,
        dtype_bytes=wl.dtype_bytes,
    )
    mean_tiles = _mean_kv_tiles(wl)
    accesses_per_wg = 1 + 2 * mean_tiles
    total_wgs = grid.total_wgs
    accesses = total_wgs * accesses_per_wg

    if a * info.kv_bytes <= topo.cache_bytes:
        # Resident regime: each domain cold-loads its ACCs' KV once.
        unique_accs = grid.batch * grid.num_accs
        cold = unique_accs * (2 * wl.kv_tiles_total) / max(topo.num_domains, 1)
        # naive mappings replicate ACCs across all domains:
        if mapping in (swizzle.NAIVE_HEAD_FIRST, swizzle.NAIVE_BLOCK_FIRST):
            cold *= topo.num_domains
        hit = max(0.0, 1.0 - cold * topo.num_domains / accesses)
    else:
        # Streaming regime: leader-miss / follower-hit within each stream,
        # if the drift window of `a` concurrent streams fits in cache.
        window_bytes = a * drift_tiles * 2 * wl.kv_tile_bytes * (w / a)
        if window_bytes <= topo.cache_bytes:
            hit = max(0.0, 1.0 - a / w)
        else:
            hit = 0.02  # residual (Q tiles, boundary reuse)

    flops = total_wgs * mean_tiles * wl.flops_per_tile_pair
    hbm_bytes = (1 - hit) * accesses * 2 * wl.kv_tile_bytes
    t_compute = flops / topo.peak_flops
    t_mem = hbm_bytes / topo.hbm_bw
    return AnalyticEstimate(
        mapping=mapping,
        hit_rate=hit,
        time=max(t_compute, t_mem),
        hbm_bytes=hbm_bytes,
        flops=flops,
    )


def relative_performance(
    wl: AttentionWorkload,
    topo: Topology,
    baseline: str = swizzle.SWIZZLED_HEAD_FIRST,
    mappings=swizzle.ALL_MAPPINGS,
) -> Dict[str, float]:
    """Throughput of each mapping relative to the baseline (paper Figs 12/14/15)."""
    ests = {m: estimate(m, wl, topo) for m in mappings}
    base = ests[baseline].throughput
    return {m: (e.throughput / base if base else 0.0) for m, e in ests.items()}
