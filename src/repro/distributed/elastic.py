"""Elastic scaling: re-mesh and reshard a run onto a different chip count.

Scenario: a 512-chip job loses a pod (or gains one). The checkpoint is
mesh-agnostic (shard files + global indices), so scaling is:

  1. pick the new mesh for the surviving chip count (`choose_mesh_shape`
     keeps the model axis if possible — ACC-aligned head sharding must keep
     dividing the KV heads' groups — and gives the remainder to data),
  2. build target shardings from the same naming-convention rules,
  3. ``checkpoint.restore(..., shardings=new)`` reassembles and re-places,
  4. the data pipeline re-shards by construction (batch = f(seed, step,
     shard)); global batch is preserved, per-shard batch changes.

`rescale_plan` is the deterministic policy piece; it is unit-tested across
chip counts, and examples/train_small.py demonstrates a live 1-device
"rescale" round trip.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro import compat
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    per_shard_batch: int


def _divisors_desc(n: int):
    return [d for d in range(n, 0, -1) if n % d == 0]


def choose_mesh_shape(
    num_devices: int,
    cfg: ModelConfig,
    *,
    prefer_model: int = 16,
    multi_pod_size: int = 256,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Pick (shape, axes) for a device count.

    model axis: the largest divisor of num_devices that is <= prefer_model
    and keeps ACC alignment (divides n_kv_heads, or n_kv_heads divides it
    while it divides n_heads). Data gets the rest; a pod axis appears when
    more than one full pod is present.
    """
    model = 1
    for d in _divisors_desc(num_devices):
        if d > prefer_model:
            continue
        acc_ok = (
            cfg.n_kv_heads % d == 0
            or (d % cfg.n_kv_heads == 0 and cfg.n_heads % d == 0)
            or cfg.ssm is not None
        )
        if acc_ok:
            model = d
            break
    rest = num_devices // model
    if num_devices > multi_pod_size and rest % (num_devices // multi_pod_size) == 0:
        pods = num_devices // multi_pod_size
        data = rest // pods
        return (pods, data, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def rescale_plan(
    old_mesh_shape: Tuple[int, ...],
    new_num_devices: int,
    cfg: ModelConfig,
    global_batch: int,
) -> RescalePlan:
    shape, axes = choose_mesh_shape(new_num_devices, cfg)
    data_shards = 1
    for n, a in zip(shape, axes):
        if a in ("pod", "data"):
            data_shards *= n
    if global_batch % data_shards:
        raise ValueError(
            f"global batch {global_batch} not divisible across {data_shards} data shards"
        )
    return RescalePlan(
        old_shape=tuple(old_mesh_shape),
        new_shape=shape,
        axis_names=axes,
        global_batch=global_batch,
        per_shard_batch=global_batch // data_shards,
    )


def make_mesh_for(num_devices: int, cfg: ModelConfig) -> Mesh:
    shape, axes = choose_mesh_shape(num_devices, cfg)
    return compat.make_mesh(shape, axes)
