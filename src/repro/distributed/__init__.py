"""repro subpackage."""
