"""Sharding rules: parameter PartitionSpecs by tree-path naming convention.

Weight-dict keys carry semantic suffixes (models/layers.py):

  _dm   (d_model, d_out)        -> shard the output dim on "model"
  _md   (d_in, d_model)         -> shard the input dim on "model"
  _vd   (vocab, d_model)        -> shard vocab on "model"
  _kvd  (K, vocab, d_model)     -> shard vocab on "model"
  _edm  (E, d_model, d_ff)      -> expert parallelism: shard E on "model"
  _emd  (E, d_ff, d_model)      -> shard E on "model"
  _de   (d_model, E) router     -> replicated
  _r / norms / small vectors    -> replicated

Stacked scan-over-period parameters carry one extra leading dim, handled by
right-aligning the spec. The attention q/k/v `_dm` sharding IS the paper's
technique at mesh scale: heads are emitted ACC-contiguously
(``core.placement.ACC_ALIGNED``), so a block-sharded head axis keeps whole
KV groups per chip — no KV duplication, no attention collectives. Striped
placement (the paper's naive baseline) is exposed for the benchmark
comparison via ``placement_strategy="striped"``.

Batch/activation rules: batch shards over ("pod", "data"); sequence over
"model" only for the long-context decode cells (KV cache too big per chip).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")  # whichever exist in the mesh


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def _right_align(spec_tail: Tuple[Optional[str], ...], rank: int) -> P:
    entries = list((None,) * (rank - len(spec_tail)) + tuple(spec_tail))
    while entries and entries[-1] is None:  # canonical form: no trailing Nones
        entries.pop()
    return P(*entries)


_SUFFIX_RULES = (
    ("_kvd", (None, MODEL_AXIS, None)),
    ("_edm", (MODEL_AXIS, None, None)),
    ("_emd", (MODEL_AXIS, None, None)),
    ("_vd", (MODEL_AXIS, None)),
    ("_dm", (None, MODEL_AXIS)),
    ("_md", (MODEL_AXIS, None)),
    ("_de", (None, None)),
    ("_r", ()),
)


def spec_for_path(path: Tuple[Any, ...], leaf) -> P:
    """PartitionSpec for one parameter leaf from its tree path."""
    key = ""
    for entry in reversed(path):
        if hasattr(entry, "key"):
            key = str(entry.key)
            break
        if isinstance(entry, str):
            key = entry
            break
    rank = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    for suffix, tail in _SUFFIX_RULES:
        if key.endswith(suffix):
            if len(tail) > rank:  # e.g. scalar gates
                return P()
            return _right_align(tail, rank)
    return P()  # norms, biases, scalars: replicated


def fix_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Repair divisibility: move mesh axes off dims they don't divide.

    Real configs are full of awkward dims — vocab 50280 or 32001, 8 experts
    under a 16-way model axis. For each sharded dim that the axis product
    does not divide, re-home the axes onto the largest other dim that
    divides (e.g. embedding: vocab -> d_model; stacked expert weights:
    expert dim -> per-expert d_ff); replicate as the last resort.
    """
    sizes = dict(mesh.shape)
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def axes_size(ax) -> int:
        axs = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axs:
            n *= sizes.get(a, 1)
        return n

    for i, ax in enumerate(entries):
        if ax is None:
            continue
        if shape[i] % axes_size(ax) == 0:
            continue
        entries[i] = None
        candidates = sorted(
            (j for j in range(len(shape)) if entries[j] is None and j != i),
            key=lambda j: -shape[j],
        )
        for j in candidates:
            if shape[j] % axes_size(ax) == 0 and shape[j] >= axes_size(ax):
                entries[j] = ax
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_specs(params_shape, mesh: Optional[Mesh] = None) -> Any:
    """Tree of PartitionSpecs matching a (shape-)tree of parameters.

    With ``mesh``, specs are divisibility-repaired against the leaf shapes.
    """

    def one(path, leaf):
        s = spec_for_path(path, leaf)
        if mesh is not None:
            s = fix_spec(s, leaf.shape, mesh)
        return s

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(mesh: Mesh, params_shape) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh)
    )


# -----------------------------------------------------------------------------
# Batch / activation / cache specs
# -----------------------------------------------------------------------------


def data_shards(mesh: Mesh) -> int:
    n = 1
    for a in _data_axes(mesh):
        n *= mesh.shape[a]
    return n


def batch_spec(mesh: Mesh, global_batch: Optional[int] = None) -> P:
    """Batch axis spec; replicated when the batch doesn't divide the data
    axes (long_500k has global_batch=1)."""
    axes = _data_axes(mesh)
    if global_batch is not None and (
        not axes or global_batch % data_shards(mesh)
    ):
        return P(None)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def tokens_spec(mesh: Mesh, ndim: int = 2) -> P:
    """tokens (B, S[, K]) — batch over data axes."""
    b = batch_spec(mesh)
    return P(b[0] if len(b) else None, *([None] * (ndim - 1)))


def activation_spec(mesh: Mesh) -> P:
    """(B, S, D) activations: batch on data, features on model."""
    b = batch_spec(mesh)
    return P(b[0] if len(b) else None, None, MODEL_AXIS)


def kv_cache_spec(mesh: Mesh, *, shard_seq: bool = False) -> P:
    """(B, Hkv, S, hd): batch on data; heads on model (ACC-aligned) unless
    the config demands sequence sharding (long_500k: B=1, S=512k)."""
    b = batch_spec(mesh)
    bax = b[0] if len(b) else None
    if shard_seq:
        return P(bax, None, MODEL_AXIS, None)
    return P(bax, MODEL_AXIS, None, None)


def cache_specs(cfg: ModelConfig, mesh: Mesh, caches_shape, *,
                shard_seq: bool = False, global_batch: Optional[int] = None):
    """Specs for the full cache tree emitted by transformer.init_caches."""
    b = batch_spec(mesh, global_batch)
    bax = b[0] if len(b) else None

    def spec(path, leaf):
        key = ""
        for entry in reversed(path):
            if hasattr(entry, "key"):
                key = str(entry.key)
                break
        rank = leaf.ndim
        if key in ("k", "v"):
            tail = kv_cache_spec(mesh, shard_seq=shard_seq)
            if bax is None:
                tail = P(None, *tuple(tail)[1:])
        elif key == "ssm":  # (B, H, P, N)
            tail = P(bax, MODEL_AXIS, None, None)
        elif key == "conv":  # (B, W-1, C)
            tail = P(bax, None, MODEL_AXIS)
        else:
            tail = P()
        pad = (None,) * (rank - len(tail))
        return fix_spec(P(*(pad + tuple(tail))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, caches_shape)


def kv_head_shards(
    num_kv_heads: int, num_devices: int
) -> Tuple[Tuple[int, int], ...]:
    """Per-device half-open KV-head ranges under the head-sharded pool.

    This is the block decomposition ``NamedSharding`` applies to the pool's
    leading head axis — contiguous equal blocks, the mesh-tier image of
    ``cache.layout.device_of_head`` (which tests pin against this). The
    serving mesh requires ``num_devices`` to divide ``num_kv_heads``
    (backends validate with a clear error), so every range has width
    ``Hkv // D``."""
    if num_devices <= 1:
        return ((0, num_kv_heads),)
    if num_kv_heads % num_devices:
        raise ValueError(
            f"num_kv_heads={num_kv_heads} must divide evenly over "
            f"num_devices={num_devices} for the head-sharded pool"
        )
    per = num_kv_heads // num_devices
    return tuple((d * per, (d + 1) * per) for d in range(num_devices))


def paged_cache_specs(mesh: Mesh, caches_shape):
    """Specs for the paged cache tree (transformer.init_paged_caches):
    shard the pool's leading KV-head axis on "model" so every page slice
    lives in its owning device's HBM — the PR-2 head-major layout is what
    makes this split natural. Page *tables* stay replicated host-side.

    Pool arrays are ``(Hkv, num_pages, ps, hd)`` per rem layer and
    ``(n_periods, Hkv, num_pages, ps, hd)`` for scanned stacks — the head
    axis is rank-4-from-the-right in both, so the spec right-aligns.
    Quantized pools add ``(Hkv, num_pages)`` scale metadata (scanned:
    ``(n_periods, Hkv, num_pages)``): same head split, rank-2-from-the-
    right, so each device holds exactly the scales its page slices
    dequantize with. Non-pool leaves (conv/ssm states, if any)
    replicate."""

    def spec(path, leaf):
        key = ""
        for entry in reversed(path):
            if hasattr(entry, "key"):
                key = str(entry.key)
                break
        rank = leaf.ndim
        if key in ("k_pages", "v_pages") and rank >= 4:
            tail = P(MODEL_AXIS, None, None, None)
            pad = (None,) * (rank - len(tail))
            return fix_spec(P(*(pad + tuple(tail))), leaf.shape, mesh)
        if key in ("k_scales", "v_scales") and rank >= 2:
            tail = P(MODEL_AXIS, None)
            pad = (None,) * (rank - len(tail))
            return fix_spec(P(*(pad + tuple(tail))), leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(spec, caches_shape)


def shard_moe_buffers(mesh: Optional[Mesh], mode: str = "ep"):
    """Constraint function threaded into models.moe.

    mode="ep":    (E, C, D) buffers shard experts on "model" — the canonical
                  expert-parallel layout. When E < model shards (Mixtral's 8
                  under a 16-way axis) fix_spec re-homes the axis to the
                  capacity dim.
    mode="ep_dp": experts on "model" AND capacity on the data axes — the
                  expert GEMMs then shard over the full mesh instead of
                  leaving every data replica to redo all expert compute
                  (a 16x compute reduction on the production mesh; see
                  EXPERIMENTS.md §Perf, mixtral hillclimb)."""
    if mesh is None:
        return lambda t: t
    tail: Tuple = (MODEL_AXIS, None, None)
    if mode == "ep_dp":
        tail = (MODEL_AXIS, _data_axes(mesh) or None, None)

    def f(t):
        spec = fix_spec(P(*tail), t.shape, mesh)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return f


def logical_constraint(mesh: Optional[Mesh], x, spec: P):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
