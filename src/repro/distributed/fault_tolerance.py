"""Fault-tolerance primitives for multi-pod training.

What runs where:
  * StragglerWatchdog — per-step wall-time EMA + deadline; on breach it
    records the event and calls the (pluggable) mitigation hook. On a real
    deployment the hook maps to: exclude the slow replica from the next
    allocation (pod-level), or re-dispatch its shard (data-level). The
    policy logic and bookkeeping are fully implemented and unit-tested; the
    actuation layer is a callback because this container has one host.
  * HeartbeatFile — liveness marker per process; the launcher's supervisor
    restarts ranks whose heartbeat goes stale (standard k8s/xmanager
    pattern). Written atomically.
  * StepFailure — exception type the trainer's retry loop recognizes; fault
    injection in tests raises it to exercise restore-and-replay.

Recovery model (trainer.py): deterministic data (batch = f(seed, step)) +
atomic checkpoints => crash anywhere, restart anywhere, replay exactly.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Callable, List, Optional


class StepFailure(RuntimeError):
    """A step-level fault (collective timeout, preemption, injected)."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    deadline: float


class StragglerWatchdog:
    """EMA-based step-deadline detector with pluggable mitigation."""

    def __init__(
        self,
        deadline_factor: float = 3.0,
        ema_alpha: float = 0.1,
        warmup_steps: int = 3,
        on_straggler: Optional[Callable[[StragglerEvent], None]] = None,
    ):
        self.deadline_factor = deadline_factor
        self.ema_alpha = ema_alpha
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.observed = 0
        self.events: List[StragglerEvent] = []

    @property
    def straggler_count(self) -> int:
        return len(self.events)

    @property
    def deadline(self) -> Optional[float]:
        if self.ema is None or self.observed < self.warmup_steps:
            return None
        return self.deadline_factor * self.ema

    def observe(self, step: int, duration: float) -> bool:
        """Returns True if this step breached the deadline."""
        breach = False
        dl = self.deadline
        if dl is not None and duration > dl:
            ev = StragglerEvent(step=step, duration=duration, deadline=dl)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            breach = True
            # Breaching steps do not poison the EMA.
        else:
            self.ema = (
                duration
                if self.ema is None
                else (1 - self.ema_alpha) * self.ema + self.ema_alpha * duration
            )
        self.observed += 1
        return breach


class HeartbeatFile:
    """Atomic liveness marker: supervisor restarts ranks with stale beats."""

    def __init__(self, path: str, rank: int = 0):
        self.path = path
        self.rank = rank

    def beat(self, step: int):
        payload = {"rank": self.rank, "step": step, "time": time.time()}
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d)
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def is_stale(self, timeout: float) -> bool:
        a = self.age()
        return a is None or a > timeout


def failure_injector(fail_at_steps, exc=StepFailure):
    """Test helper: raises at the given steps exactly once each."""
    remaining = set(fail_at_steps)

    def hook(step: int):
        if step in remaining:
            remaining.discard(step)
            raise exc(f"injected failure at step {step}")

    return hook
