"""Observability example: serve with the full telemetry stack attached.

Construct the engine with ``telemetry=Telemetry.create()`` and three
measured views come out of one run:

  * the **metrics registry** — counters/gauges/histograms the engine
    updates through pre-bound instruments (printed here as Prometheus
    text exposition);
  * the **tracer** — step-level spans (schedule / flush / decode) plus
    per-request lifecycle events, exported as a Chrome ``trace_event``
    file you can drop into https://ui.perfetto.dev, and reduced to
    measured TTFT / inter-token latencies;
  * the **drift report** — measured decode-step time vs the analytic
    NUMA model's prediction per (batch, context) cell.

Leave ``telemetry`` off and the engine threads shared no-op instruments
instead — nothing is allocated per step.

Run: PYTHONPATH=src python examples/serve_traced.py
"""

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.obs import Telemetry
from repro.serving import LLMEngine, Request, SamplingParams


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    telemetry = Telemetry.create()
    engine = LLMEngine(
        cfg, params, kv_layout="paged", max_batch=4, num_pages=96,
        page_size=16, max_pages_per_seq=8, prompt_buckets=(16, 32, 64),
        telemetry=telemetry,
    )

    rng = np.random.default_rng(0)
    system = rng.integers(1, cfg.vocab, size=(16,))  # shared prefix
    reqs = []
    for uid in range(5):
        tail = rng.integers(1, cfg.vocab, size=(int(rng.integers(4, 14)),))
        prompt = np.concatenate([system, tail]) if uid % 2 else tail
        reqs.append(Request(
            uid=uid, prompt=prompt,
            sampling=SamplingParams(temperature=0.7, max_tokens=6, seed=uid),
        ))
    engine.generate(reqs)

    # 1. Metrics: Prometheus text exposition of everything the engine
    #    counted and timed.
    print(telemetry.metrics.render_prometheus())

    # 2. Tracing: measured per-request latencies from lifecycle events,
    #    and the Perfetto-loadable trace file.
    for uid, lat in sorted(telemetry.tracer.request_latencies().items()):
        itl = lat["itl"]
        print(f"req {uid}: ttft={lat['ttft'] * 1e3:.1f}ms "
              f"e2e={lat['e2e'] * 1e3:.1f}ms "
              f"mean itl={np.mean(itl) * 1e3:.1f}ms ({len(itl)} intervals) "
              f"preemptions={lat['preemptions']}")
    path = telemetry.tracer.write_chrome_trace(
        "artifacts/traces/serve_traced.json")
    print(f"\nwrote {path} (open in https://ui.perfetto.dev)")

    # 3. Drift: measured decode-step time vs the analytic model, per
    #    (batch, context) cell. On CPU interpret mode the ratios are
    #    huge — the model prices accelerator HBM — the *trend* across
    #    runs is the signal.
    print()
    print(telemetry.drift.report(engine.drift_model_fn()).render())
    print()
    print(engine.stats().summary())
    engine.close()


if __name__ == "__main__":
    main()
