"""Batched serving example: one LLMEngine facade, both KV layouts.

Demonstrates the PR-5 serving API over a mixed request stream:

  * ``kv_layout="dense"`` — slot-based continuous batching over dense
    cache stripes;
  * ``kv_layout="paged"`` — page-pool admission, per-token page append,
    and prefix sharing: the requests below share a system prompt, so
    every request after the first reuses its pages and prefills only the
    tail;
  * per-request ``SamplingParams`` (greedy and seeded temperature rows in
    the same batch) sampled on device by one jitted batched sampler.

Both ride the decode kernel path (one KV fetch per (batch, kv-head) grid
cell — the paper's ACC insight applied to decode); the paged pool is
head-major, i.e. NUMA head-aligned placement by construction. The
scheduler prices admission with the analytic NUMA decode model.

Run: PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams


def make_requests(cfg, rng, n=10, shared_prefix_len=32):
    system = rng.integers(1, cfg.vocab, size=(shared_prefix_len,))
    reqs = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab, size=(int(rng.integers(4, 28)),))
        prompt = np.concatenate([system, tail]) if i % 5 else tail
        reqs.append(Request(
            uid=i,
            prompt=prompt,
            sampling=SamplingParams(
                temperature=0.0 if i % 2 == 0 else 0.8,
                max_tokens=int(rng.integers(4, 12)),
                seed=i,
            ),
        ))
    return reqs


def drive(engine, requests):
    name = engine.kv_layout
    print(f"[{name}] serving {len(requests)} requests")
    t0 = time.time()
    results = engine.generate(requests)
    dt = time.time() - t0
    new_tokens = sum(len(r.tokens) for r in results)
    print(f"[{name}] completed in {dt:.1f}s — {new_tokens} new tokens "
          f"({new_tokens/dt:.1f} tok/s incl. compile)")
    for r in sorted(results, key=lambda r: r.uid):
        toks = [int(np.asarray(t).reshape(-1)[0]) for t in r.tokens]
        print(f"  req {r.uid:2d} (prompt {r.prompt_len:2d} tok, "
              f"{r.finish_reason}) -> {toks}")
    return results


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    requests = make_requests(cfg, rng)

    dense = LLMEngine(
        cfg, params, kv_layout="dense", max_batch=4, cache_len=256,
        prompt_buckets=(32, 64),
    )
    drive(dense, [r.clone() for r in requests])
    print(dense.stats().summary())

    paged = LLMEngine(
        cfg, params, kv_layout="paged", max_batch=4, num_pages=96,
        page_size=16, max_pages_per_seq=8, prompt_buckets=(16, 32, 64),
    )
    drive(paged, requests)
    print(paged.stats().summary())
    print(f"[paged] analytic steady-state layout pick: "
          f"{paged.backend.modeled_kv_layout()}")


if __name__ == "__main__":
    main()
