"""Batched serving example: continuous batching over a mixed request stream.

Demonstrates the serving half of the framework: bucketed prefill, slot-based
continuous batching, EOS/max-token termination, and the decode kernel path
(one KV fetch per (batch, kv-head) grid cell — the paper's ACC insight
applied to decode).

Run: PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(
        cfg, params, num_slots=4, cache_len=256, prompt_buckets=(32, 64),
    )
    rng = np.random.default_rng(0)
    requests = [
        Request(
            uid=i,
            prompt=rng.integers(1, cfg.vocab, size=(int(rng.integers(8, 60)),)),
            max_new_tokens=int(rng.integers(4, 12)),
            temperature=0.0 if i % 2 == 0 else 0.8,
        )
        for i in range(10)
    ]
    print(f"serving {len(requests)} requests on {engine.num_slots} slots "
          f"(continuous batching)")
    t0 = time.time()
    results = engine.run(requests)
    dt = time.time() - t0
    new_tokens = sum(len(r.tokens) for r in results)
    print(f"completed in {dt:.1f}s — {new_tokens} new tokens "
          f"({new_tokens/dt:.1f} tok/s incl. compile)")
    for r in sorted(results, key=lambda r: r.uid):
        toks = [int(np.asarray(t).reshape(-1)[0]) for t in r.tokens]
        print(f"  req {r.uid:2d} (prompt {r.prompt_len:2d} tok) -> {toks}")


if __name__ == "__main__":
    main()
