"""Batched serving example: continuous batching over a mixed request stream.

Demonstrates the serving half of the framework, both control planes:

  * dense slots (``ServingEngine``): bucketed prefill, slot-based
    continuous batching, EOS/max-token termination;
  * paged KV (``PagedServingEngine``): page-pool admission, per-token page
    append, and prefix sharing — the requests below share a system prompt,
    so every request after the first reuses its pages and prefills only
    the tail.

Both ride the decode kernel path (one KV fetch per (batch, kv-head) grid
cell — the paper's ACC insight applied to decode); the paged engine's page
pool is head-major, i.e. NUMA head-aligned placement by construction.

Run: PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import PagedServingEngine, Request, ServingEngine


def make_requests(cfg, rng, n=10, shared_prefix_len=32):
    system = rng.integers(1, cfg.vocab, size=(shared_prefix_len,))
    reqs = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab, size=(int(rng.integers(4, 28)),))
        prompt = np.concatenate([system, tail]) if i % 5 else tail
        reqs.append(
            Request(
                uid=i,
                prompt=prompt,
                max_new_tokens=int(rng.integers(4, 12)),
                temperature=0.0 if i % 2 == 0 else 0.8,
            )
        )
    return reqs


def drive(name, engine, requests):
    print(f"[{name}] serving {len(requests)} requests")
    t0 = time.time()
    results = engine.run(requests)
    dt = time.time() - t0
    new_tokens = sum(len(r.tokens) for r in results)
    print(f"[{name}] completed in {dt:.1f}s — {new_tokens} new tokens "
          f"({new_tokens/dt:.1f} tok/s incl. compile)")
    for r in sorted(results, key=lambda r: r.uid):
        toks = [int(np.asarray(t).reshape(-1)[0]) for t in r.tokens]
        print(f"  req {r.uid:2d} (prompt {r.prompt_len:2d} tok) -> {toks}")
    return results


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    requests = make_requests(cfg, rng)

    dense = ServingEngine(
        cfg, params, num_slots=4, cache_len=256, prompt_buckets=(32, 64),
    )
    drive("dense", dense, [Request(**vars(r)) for r in requests])

    paged = PagedServingEngine(
        cfg, params, num_pages=96, page_size=16, max_batch=4,
        max_pages_per_seq=8, prompt_buckets=(16, 32, 64),
    )
    drive("paged", paged, requests)
    stats = paged.prefix_stats()
    print(f"[paged] prefix hit rate {stats['prefix_hit_rate']:.2f} "
          f"({int(stats['pages_reused'])}/{int(stats['prompt_pages'])} prompt "
          f"pages reused), {int(stats['preemptions'])} preemptions, "
          f"layout pick: {paged.kv_layout}")


if __name__ == "__main__":
    main()
