"""Streaming example: consume LLMEngine.step() token deltas as they land.

``generate()`` is the blocking convenience; the real serving surface is
``add_request()`` + ``step()``: each tick returns one ``RequestOutput``
per request that gained tokens, carrying only the *new* tokens (so a UI
can append them immediately) and, on the final delta, a
``finish_reason``. Requests can join mid-stream — continuous batching is
the default, not a mode.

Run: PYTHONPATH=src python examples/serve_stream.py
"""

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    engine = LLMEngine(
        cfg, params, kv_layout="auto", max_batch=4, num_pages=96,
        page_size=16, max_pages_per_seq=8, prompt_buckets=(16, 32, 64),
    )
    print(f"kv_layout=auto resolved to {engine.kv_layout}")

    rng = np.random.default_rng(0)
    for uid in range(2):
        engine.add_request(Request(
            uid=uid,
            prompt=rng.integers(1, cfg.vocab, size=(12,)),
            sampling=SamplingParams(temperature=0.7, max_tokens=8, seed=uid),
        ))

    streams = {}
    tick = 0
    late_joined = False
    while True:
        outputs = engine.step()
        for out in outputs:
            toks = [int(np.asarray(t).reshape(-1)[0]) for t in out.new_tokens]
            streams.setdefault(out.uid, []).extend(toks)
            tag = f" <{out.finish_reason}>" if out.finished else ""
            print(f"tick {tick:2d} | req {out.uid}: +{toks}{tag}")
        tick += 1
        if tick == 3 and not late_joined:
            # A request arriving mid-stream joins the running batch.
            late_joined = True
            engine.add_request(
                prompt=rng.integers(1, cfg.vocab, size=(6,)),
                sampling=SamplingParams(max_tokens=5),
                uid=99,
            )
            print("tick  2 | req 99 joined the stream")
        if not engine.backend.active.any() and not engine.scheduler.has_work():
            break

    for uid, toks in sorted(streams.items()):
        print(f"req {uid}: {toks}")
    print(engine.stats().summary())


if __name__ == "__main__":
    main()
