"""Quickstart: the paper's technique in five minutes, via the public API.

1. The four workgroup mappings (paper Figs. 7-10) and how they place
   attention heads on NUMA domains.
2. The calibrated MI300X cache simulator reproducing the paper's headline
   result (swizzled head-first sustains high L2 hit rates; block-first
   collapses).
3. The Pallas kernel with the mapping realized in its grid, validated
   against the oracle, plus its static HBM-traffic analysis (the TPU
   analogue of the L2 hit rate).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import cache_sim, numa, swizzle
from repro.core.cache_sim import AttentionWorkload
from repro.core.swizzle import AttentionGrid
from repro.kernels import ops, ref
from repro.kernels.flash_attention import (
    HEAD_FIRST, BLOCK_FIRST, MappingConfig, hbm_block_fetches,
)

print("== 1. Mapping strategies (8 q-heads, 128 row blocks, 4 XCDs) ==")
grid = AttentionGrid(batch=1, num_q_heads=8, blocks_per_head=128)
for m in swizzle.ALL_MAPPINGS:
    sets = swizzle.heads_per_domain_sets(m, grid, 4)
    print(f"  {m:22s} -> heads per XCD: {[sorted(s) for s in sets]}")

print("\n== 2. Paper reproduction: MHA H=128, N_CTX=32K on MI300X ==")
wl = AttentionWorkload(
    grid=AttentionGrid(batch=1, num_q_heads=128, blocks_per_head=0),
    seq_len=32768, head_dim=128,
)
res = cache_sim.compare_mappings(wl, numa.MI300X, budget_accesses=600_000)
base = res[swizzle.SWIZZLED_HEAD_FIRST].throughput
for m, r in res.items():
    print(f"  {m:22s} L2 hit {r.hit_rate*100:5.1f}%   relative perf {r.throughput/base:.2f}x")

print("\n== 3. Pallas kernel: same attention, mapping in the grid ==")
q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 512, 64))
k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 512, 64))
v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 512, 64))
o = ops.flash_attention(q, k, v, causal=True, impl="pallas")
o_ref = ref.attention(q, k, v, causal=True)
print(f"  kernel vs oracle max err: {float(jnp.max(jnp.abs(o - o_ref))):.2e}")

for name, mc in [
    ("swizzled_head_first", MappingConfig(order=HEAD_FIRST, kv_resident=True)),
    ("naive_block_first", MappingConfig(order=BLOCK_FIRST, kv_resident=False)),
]:
    t = hbm_block_fetches(batch=1, num_q_heads=32, num_kv_heads=8,
                          seq_q=8192, seq_kv=8192, head_dim=128, mapping=mc)
    print(f"  {name:22s} HBM reuse efficiency {t['reuse_efficiency']*100:5.1f}% "
          f"(KV traffic {t['kv_bytes']/1e9:.2f} GB)")
print("\nDone. See examples/numa_sweep.py for the full paper grids.")
