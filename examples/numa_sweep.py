"""Full paper-grid NUMA sweep driver (Figs. 12-16 at paper scale).

Equivalent to ``python -m benchmarks.run --full`` but exposed as a script
with figure selection, so individual paper tables can be regenerated:

  PYTHONPATH=src:. python examples/numa_sweep.py --figure 13 --full
  PYTHONPATH=src:. python examples/numa_sweep.py --figure all
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--figure", default="all",
                    choices=["12", "13", "14", "15", "16", "all"])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from benchmarks import paper_figures as pf

    if args.figure in ("12", "13", "all"):
        rows = pf.fig12_13_mha(full=args.full)
        pf.validate_paper_claims(rows)
    if args.figure in ("14", "all"):
        pf.fig14_gqa(full=args.full)
    if args.figure in ("15", "all"):
        pf.fig15_deepseek(full=args.full)
    if args.figure in ("16", "all"):
        pf.fig16_backward(full=args.full)


if __name__ == "__main__":
    main()
