"""Long-context serving: quantized KV pages + device<->host KV tiering.

Million-token contexts are a memory-capacity problem before they are a
compute problem. Two knobs on the paged engine attack it (PR 10):

  * ``kv_dtype="int8"`` (or ``"fp8"``) stores K/V pages as quantized
    codes with one fp32 scale per (head, page) riding the page-table
    metadata — the pool shrinks to ~0.25x fp32 bytes, so the same HBM
    holds ~4x the context. Dequantization happens inside the Pallas
    kernel bodies; greedy decode on the smoke shapes matches the fp32
    argmax (pinned in tests/test_tiering.py).
  * ``host_pool_bytes=N`` puts a host-DRAM page store behind the device
    pool: under pressure, cold prefix pages *demote* to the host tier
    instead of being freed, and *promote* back on the next prefix match
    — so a working set larger than device HBM serves without
    re-prefilling (demotions replace preemptions).

This example deliberately under-sizes the device pool, then serves a
shared-prefix workload twice: the second pass round-trips through the
host tier and still reproduces the first pass bit-for-bit. It also
demonstrates the async push surface — ``engine.stream()`` with a
``detokenizer`` hook.

Run: PYTHONPATH=src python examples/serve_longctx.py
"""

import asyncio

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams


def greedy(engine, prompts, n_new, uid0=0):
    reqs = [Request(uid0 + i, p, SamplingParams(max_tokens=n_new))
            for i, p in enumerate(prompts)]
    outs = engine.generate(reqs)
    return {o.uid - uid0: [int(np.asarray(t).reshape(-1)[0])
                           for t in o.tokens] for o in outs}


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # --- quantized pool: ~4x the context in the same HBM ------------------
    fp32 = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                     page_size=8)
    int8 = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                     page_size=8, kv_dtype="int8")
    ratio = int8.backend.kv_pool_bytes() / fp32.backend.kv_pool_bytes()
    print(f"pool bytes: fp32={fp32.backend.kv_pool_bytes()} "
          f"int8={int8.backend.kv_pool_bytes()} ({ratio:.3f}x)")
    prompts = [rng.integers(1, 400, size=n) for n in (8, 17, 25, 33)]
    want = greedy(fp32, prompts, 8)
    got = greedy(int8, prompts, 8)
    print(f"int8 greedy == fp32 greedy: {got == want}")
    fp32.close()
    int8.close()

    # --- host tier: serve a working set bigger than the device pool ------
    engine = LLMEngine(
        cfg, params, kv_layout="paged", num_pages=20, page_size=8,
        host_pool_bytes=1 << 20,
    )
    shared = rng.integers(1, cfg.vocab, size=33)
    first = greedy(engine, [shared], 6)[0]
    # Pressure the pool so the shared prefix demotes host-side...
    greedy(engine, [rng.integers(1, cfg.vocab, size=40 + 8 * i)
                    for i in range(3)], 4, uid0=100)
    # ...then serve it again: pages promote back instead of re-prefilling.
    again = greedy(engine, [shared], 6, uid0=200)[0]
    st = engine.stats()
    print(f"demoted={st.demoted_pages} promoted={st.promoted_pages} "
          f"host_bytes={st.host_bytes_resident} "
          f"round-trip bit-match: {again == first}")
    print(st.summary())
    engine.close()

    # --- async push streaming with a detokenizer hook ---------------------
    engine = LLMEngine(
        cfg, params, kv_layout="paged", num_pages=64, page_size=8,
        detokenizer=lambda toks: " ".join(f"<{int(t)}>" for t in toks),
    )

    async def consume(tag, n):
        async for out in engine.stream(
                prompt=rng.integers(1, cfg.vocab, size=n),
                sampling=SamplingParams(max_tokens=6)):
            print(f"  [{tag}] {out.text}" + (" <eos>" if out.finished else ""))

    async def both():
        await asyncio.gather(consume("a", 12), consume("b", 20))

    asyncio.run(both())
    engine.close()


if __name__ == "__main__":
    main()
