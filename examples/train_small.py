"""End-to-end training driver: a ~100M-parameter llama-family model on the
synthetic corpus, with checkpoints, resume, failure injection, and elastic
restore — the full production path at laptop scale.

Demo (2-3 min on one CPU core):
  PYTHONPATH=src python examples/train_small.py --steps 30

The full deliverable run (a few hundred steps of the ~100M config):
  PYTHONPATH=src python examples/train_small.py --steps 300 --width 768 \
      --layers 12 --seq-len 512 --global-batch 8
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.fault_tolerance import failure_injector
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step
from repro.training.trainer import Trainer, TrainerConfig


def make_cfg(width: int, layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name=f"llama-{width}x{layers}",
        d_model=width,
        n_layers=layers,
        n_heads=max(4, width // 64),
        n_kv_heads=max(2, width // 256),
        head_dim=64,
        d_ff=width * 4,
        vocab=vocab,
        layer_pattern=(LayerSpec(kind="attn", ffn="mlp"),),
        tie_embeddings=True,
        compute_dtype="float32",
        max_seq_len=4096,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = make_cfg(args.width, args.layers, args.vocab)
    print(f"model: {cfg.name}  params ~{cfg.param_count()/1e6:.1f}M")
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                              total_steps=args.steps),
        microbatches=2,
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    pipe = make_pipeline(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab=cfg.vocab, ngram_vocab=64,
    ))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train_small_")
    trainer = Trainer(
        step_fn, state, pipe,
        TrainerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                      ckpt_every=max(10, args.steps // 4), ckpt_async=False,
                      log_every=max(1, args.steps // 15)),
        put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    if trainer.try_resume():
        print(f"resumed at step {trainer.step}")
    inject = (failure_injector({args.inject_failure_at})
              if args.inject_failure_at else None)
    import logging
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    metrics = trainer.run(inject_failure=inject)
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(trainer.history)} steps")
    print(f"throughput {metrics.get('tokens_per_s', 0):.0f} tok/s; "
          f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
